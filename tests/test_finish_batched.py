"""Batched finish verdicts (TuningService.finish_many / finish_later).

Contract: J jobs finished in one drain produce TuneDecisions IDENTICAL to
J sequential ``finish()`` calls — bitwise, not approximately: the
matrix-free verdict scorer's per-cell arithmetic and the host-side
per-query moment folds are both independent of how verdicts batch —
while ``offline_dispatch_count`` grows per drain instead of per job.
"""
import numpy as np
import pytest

from repro import mrsim
from repro.core.database import ReferenceDB, SeriesBank, pack_series
from repro.core.filters import preprocess_bank
from repro.serve.tuning import TuningService


@pytest.fixture(scope="module")
def paper_bank():
    psets = mrsim.paper_param_sets()
    series, labels = [], []
    for app in ("wordcount", "terasort"):
        for p in psets:
            series.append(mrsim.simulate_cpu_series(app, p, dt=0.25))
            labels.append(app)
    bank = pack_series(series, labels=labels)
    return SeriesBank(preprocess_bank(bank.series, bank.lengths),
                      bank.lengths, bank.labels, bank.entries)


@pytest.fixture(scope="module")
def queries():
    p = mrsim.paper_param_sets()[0]
    return {f"{app}{r}": mrsim.simulate_cpu_series(app, p, run=r, dt=0.25)
            for app in ("wordcount", "terasort", "exim") for r in (1, 2)}


def _decisions_equal(a, b):
    assert a.workload == b.workload
    assert a.matched == b.matched
    assert a.corr == b.corr                     # bitwise, not approx
    assert a.scores == b.scores
    assert a.config == b.config
    assert a.decided_at_fraction == b.decided_at_fraction
    assert a.final and b.final


def _run(svc, queries, chunk=16):
    for jid, q in queries.items():
        svc.submit(jid, expected_len=len(q))
    n = max(len(q) for q in queries.values())
    for lo in range(0, n, chunk):
        for jid, q in queries.items():
            svc.push(jid, q[lo: lo + chunk])
        svc.tick()


def test_finish_many_equals_sequential_finish(paper_bank, queries):
    svc_seq = TuningService(paper_bank, band=16, denoise=True)
    svc_bat = TuningService(paper_bank, band=16, denoise=True)
    _run(svc_seq, queries)
    _run(svc_bat, queries)

    ids = list(queries)
    seq = {jid: svc_seq.finish(jid) for jid in ids}
    bat = svc_bat.finish_many(ids)
    assert set(bat) == set(ids)
    for jid in ids:
        _decisions_equal(bat[jid], seq[jid])
    # the whole point: J verdicts, ONE batched dispatch (sublinear in J)
    assert svc_seq.offline_dispatch_count == len(ids)
    assert svc_bat.offline_dispatch_count == 1
    assert svc_bat.n_active == 0
    # slots all freed
    svc_bat.submit("again", expected_len=32)


def test_finish_many_drains_buffers_once(paper_bank, queries):
    """Buffered samples are flushed by ONE internal tick for the whole
    batch (sequential finishes reach the same state because the first
    finish's tick drains every job's buffer)."""
    svc = TuningService(paper_bank, band=16, denoise=True, threshold=0.85)
    for jid, q in queries.items():
        svc.submit(jid, expected_len=len(q))
        svc.push(jid, q)                      # everything still buffered
    dispatches_before = svc.dispatch_count
    out = svc.finish_many(list(queries))
    assert svc.dispatch_count == dispatches_before + 1
    assert svc.offline_dispatch_count == 1
    for jid in queries:
        assert out[jid].final and set(out[jid].scores) == {"wordcount",
                                                           "terasort"}
    # the text-parse family resolves to wordcount (paper Table-1)
    assert out["wordcount1"].matched == "wordcount"
    assert out["exim1"].matched == "wordcount"


def test_finish_many_rejects_duplicates_and_unknown(paper_bank):
    svc = TuningService(paper_bank)
    svc.submit("a", expected_len=8)
    with pytest.raises(ValueError, match="duplicate"):
        svc.finish_many(["a", "a"])
    with pytest.raises(KeyError, match="ghost"):
        svc.finish_many(["a", "ghost"])
    assert svc.finish_many([]) == {}
    assert "a" in svc._jobs                   # failed calls retired nothing


def test_finish_later_drain_queue(paper_bank, queries):
    """finish_later frees the slot immediately, parks the verdict, and a
    drain renders every queued verdict in ONE dispatch — identical to
    what finish() would have produced."""
    svc_ref = TuningService(paper_bank, band=16, denoise=True)
    svc = TuningService(paper_bank, band=16, denoise=True, slots=6,
                        finish_batch=64)
    _run(svc_ref, queries)
    _run(svc, queries)
    want = {jid: svc_ref.finish(jid) for jid in queries}

    for jid in queries:
        svc.finish_later(jid)
    assert svc.n_active == 0                  # slots free before any drain
    assert svc.pending_finishes == len(queries)
    assert svc.offline_dispatch_count == 0    # nothing rendered yet
    svc.submit("reuse", expected_len=16)      # slot genuinely reusable
    out = svc.drain_finishes()
    assert svc.pending_finishes == 0
    assert svc.offline_dispatch_count == 1
    assert set(out) == set(queries)
    for jid in queries:
        _decisions_equal(out[jid], want[jid])


def test_finish_later_auto_drains_at_batch_size(paper_bank, queries):
    ids = list(queries)
    svc = TuningService(paper_bank, band=16, denoise=True, slots=6,
                        finish_batch=3)
    _run(svc, queries)
    for jid in ids[:2]:
        svc.finish_later(jid)
    assert svc.pending_finishes == 2 and svc.offline_dispatch_count == 0
    svc.finish_later(ids[2])                  # hits finish_batch=3
    # rendered but NOT yet delivered: still owed to the caller, so the
    # `if pending_finishes: drain_finishes()` polling idiom works
    assert svc.pending_finishes == 3
    assert svc.offline_dispatch_count == 1
    # auto-drained decisions are delivered by the next drain_finishes,
    # alongside any later queue
    for jid in ids[3:]:
        svc.finish_later(jid)
    out = svc.drain_finishes()
    assert set(out) == set(ids)
    assert svc.offline_dispatch_count == 2    # 6 verdicts, 2 dispatches
    assert svc.drain_finishes() == {}


def test_finish_later_refuses_reused_id_with_pending_verdict(paper_bank):
    """A pending verdict claims its job id until delivered: deferring a
    reused id would silently drop one of the two decisions (the drain
    dict is keyed by id), so it must refuse instead."""
    q = mrsim.simulate_cpu_series("wordcount", mrsim.paper_param_sets()[0],
                                  run=1, dt=0.25)
    svc = TuningService(paper_bank, band=16, denoise=True,
                        finish_batch=64)
    svc.submit("a", expected_len=len(q))
    svc.push("a", q)
    svc.tick()
    svc.finish_later("a")
    svc.submit("a", expected_len=len(q))      # id reuse itself is fine
    svc.push("a", q)
    svc.tick()
    with pytest.raises(ValueError, match="already pending"):
        svc.finish_later("a")
    out = svc.drain_finishes()                # delivers the first verdict
    assert set(out) == {"a"}
    svc.finish_later("a")                     # now the id is free again
    assert set(svc.drain_finishes()) == {"a"}


def test_finish_later_records_history_and_sublinear_dispatches():
    """DB-backed drain records every decision; dispatch count stays
    sublinear in completions (the acceptance-criteria pin)."""
    p = mrsim.paper_param_sets()[0]
    db = ReferenceDB()
    for app in ("wordcount", "terasort"):
        s = mrsim.simulate_cpu_series(app, p, dt=0.25)
        db.add(app, {"p": 0}, preprocess_bank(
            s[None].astype(np.float32),
            np.asarray([len(s)], np.int32))[0])
    svc = TuningService(db, band=16, denoise=True, slots=8,
                        finish_batch=4)
    q = mrsim.simulate_cpu_series("wordcount", p, run=1, dt=0.25)
    n_jobs = 8
    for j in range(n_jobs):
        svc.submit(f"j{j}", expected_len=len(q))
        svc.push(f"j{j}", q)
    svc.tick()
    for j in range(n_jobs):
        svc.finish_later(f"j{j}")
    out = svc.drain_finishes()
    assert len(out) == n_jobs
    assert svc.offline_dispatch_count == 2    # two finish_batch=4 drains
    assert svc.offline_dispatch_count < n_jobs
    assert len(db.decision_history(matched="wordcount")) == n_jobs
