"""Haar wavelet compression (paper §5 future plan)."""
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import wavelet


@given(st.integers(0, 2**31 - 1), st.integers(2, 200))
@settings(max_examples=25, deadline=None)
def test_perfect_reconstruction(seed, n):
    x = np.random.default_rng(seed).normal(size=n)
    xr = wavelet.reconstruct(wavelet.haar_dwt(x), n)
    np.testing.assert_allclose(x, xr, atol=1e-9)


def test_compression_keeps_top_energy():
    x = np.sin(np.linspace(0, 4 * np.pi, 128))
    c_full = wavelet.haar_dwt(x)
    c16 = wavelet.compress(x, 16)
    assert (c16 != 0).sum() <= 16
    # kept coefficients carry most of the energy
    assert np.sum(c16 ** 2) >= 0.95 * np.sum(c_full ** 2)


def test_wavelet_similarity_self():
    x = np.random.default_rng(0).normal(size=100)
    assert wavelet.wavelet_similarity(x, x) > 0.999


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_streaming_haar_equals_offline_at_every_chunk_boundary(seed):
    """StreamingHaar prefix coefficients == offline haar_dwt of the same
    edge-extended prefix, bitwise, at EVERY chunk boundary of a random
    chunking — the soundness contract the online prefilter rests on."""
    rng = np.random.default_rng(seed)
    total = int(rng.integers(2, 200))
    x = rng.normal(size=total)
    sh = wavelet.StreamingHaar(total)
    lo = 0
    while lo < total:
        c = int(rng.integers(1, max(2, total // 3)))
        sh.update(x[lo: lo + c])
        lo = min(lo + c, total)
        prefix = np.pad(x[:lo], (0, sh.size - lo), mode="edge")
        np.testing.assert_array_equal(sh.coeffs(), wavelet.haar_dwt(prefix))
    assert sh.size == wavelet._next_pow2(max(total, 2))


def test_streaming_haar_regrows_past_expected_len():
    """expected_len is a prediction: a job that overruns the power-of-two
    target regrows transparently and stays equal to the offline
    transform."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=70)
    sh = wavelet.StreamingHaar(16)          # predicted 16, actual 70
    for lo in range(0, 70, 7):
        sh.update(x[lo: lo + 7])
    assert sh.size == 128
    want = wavelet.haar_dwt(np.pad(x, (0, 128 - 70), mode="edge"))
    np.testing.assert_array_equal(sh.coeffs(), want)
    # compressed() keeps at most m nonzeros of the same coefficients
    cm = sh.compressed(16)
    assert (cm != 0).sum() <= 16
    assert set(np.flatnonzero(cm)) <= set(np.flatnonzero(want))


def test_coeff_similarity_bank_matches_offline_tail():
    """The split-out cosine tail reproduces wavelet_similarity_bank."""
    rng = np.random.default_rng(9)
    x = rng.random(100)
    bank = rng.random((5, 90)).astype(np.float64)
    lengths = np.full((5,), 90, np.int64)
    want = wavelet.wavelet_similarity_bank(x, bank, lengths, m=32)
    n = max(wavelet._next_pow2(100), wavelet._next_pow2(90))
    xp = np.pad(x, (0, n - 100), mode="edge")
    bp = np.pad(bank, ((0, 0), (0, n - 90)), mode="edge")
    cx = wavelet.compress(xp, 32)
    cb = wavelet.compress_bank(wavelet.haar_dwt_bank(bp), 32)
    np.testing.assert_array_equal(wavelet.coeff_similarity_bank(cx, cb),
                                  want)


def test_wavelet_matching_agrees_with_dtw_on_easy_cases():
    from repro import mrsim
    p = mrsim.paper_param_sets()[0]
    exim = mrsim.simulate_cpu_series("exim", p)
    wc = mrsim.simulate_cpu_series("wordcount", p)
    ts = mrsim.simulate_cpu_series("terasort", p)
    s_wc = wavelet.wavelet_similarity(exim, wc, m=64)
    s_ts = wavelet.wavelet_similarity(exim, ts, m=64)
    assert s_wc > s_ts
