"""Haar wavelet compression (paper §5 future plan)."""
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import wavelet


@given(st.integers(0, 2**31 - 1), st.integers(2, 200))
@settings(max_examples=25, deadline=None)
def test_perfect_reconstruction(seed, n):
    x = np.random.default_rng(seed).normal(size=n)
    xr = wavelet.reconstruct(wavelet.haar_dwt(x), n)
    np.testing.assert_allclose(x, xr, atol=1e-9)


def test_compression_keeps_top_energy():
    x = np.sin(np.linspace(0, 4 * np.pi, 128))
    c_full = wavelet.haar_dwt(x)
    c16 = wavelet.compress(x, 16)
    assert (c16 != 0).sum() <= 16
    # kept coefficients carry most of the energy
    assert np.sum(c16 ** 2) >= 0.95 * np.sum(c_full ** 2)


def test_wavelet_similarity_self():
    x = np.random.default_rng(0).normal(size=100)
    assert wavelet.wavelet_similarity(x, x) > 0.999


def test_wavelet_matching_agrees_with_dtw_on_easy_cases():
    from repro import mrsim
    p = mrsim.paper_param_sets()[0]
    exim = mrsim.simulate_cpu_series("exim", p)
    wc = mrsim.simulate_cpu_series("wordcount", p)
    ts = mrsim.simulate_cpu_series("terasort", p)
    s_wc = wavelet.wavelet_similarity(exim, wc, m=64)
    s_ts = wavelet.wavelet_similarity(exim, ts, m=64)
    assert s_wc > s_ts
