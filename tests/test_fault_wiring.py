"""Fault wiring of the serving front: heartbeats -> eviction, straggler
flagging, and elastic rescale — `runtime.fault` is no longer dormant.

The safety pin behind all of it: eviction and rescale move STATE, never
numbers.  Survivors of a stalled job's eviction score bit-identically to
a run that never saw the stalled job, and a rescaled service keeps
rendering the same decisions.
"""
import numpy as np
import pytest

from repro import mrsim
from repro.core.database import SeriesBank, pack_series
from repro.runtime.fault import ElasticController
from repro.serve.tuning import TuningService


@pytest.fixture(scope="module")
def paper_bank():
    from repro.core.filters import preprocess_bank

    psets = mrsim.paper_param_sets()
    series, labels = [], []
    for app in ("wordcount", "terasort"):
        for p in psets:
            series.append(mrsim.simulate_cpu_series(app, p, dt=0.25))
            labels.append(app)
    bank = pack_series(series, labels=labels)
    return SeriesBank(preprocess_bank(bank.series, bank.lengths),
                      bank.lengths, bank.labels, bank.entries)


@pytest.fixture(scope="module")
def queries():
    psets = mrsim.paper_param_sets()
    return {f"job{i}": mrsim.simulate_cpu_series(app, psets[i], run=i + 1,
                                                 dt=0.25)
            for i, app in enumerate(("wordcount", "exim", "terasort"))}


def _kw():
    return dict(band=16, threshold=0.85, margin=0.02, stable_ticks=2,
                min_fraction=0.15, denoise=True, slots=8)


def test_sweep_stalled_evicts_without_perturbing_survivors(paper_bank,
                                                           queries):
    svc = TuningService(paper_bank, heartbeat_timeout=0.5, **_kw())
    solo = TuningService(paper_bank, **_kw())   # never sees the staller
    for jid, q in queries.items():
        svc.submit(jid, expected_len=len(q))
        if jid != "job1":
            solo.submit(jid, expected_len=len(q))

    stall_after = 3
    sims_svc, sims_solo = [], []
    n = max(len(q) for q in queries.values())
    for t, lo in enumerate(range(0, n, 16)):
        now = 0.1 * t
        for jid, q in queries.items():
            if jid == "job1" and t >= stall_after:
                continue                        # job1's agent goes silent
            svc.push(jid, q[lo: lo + 16], now=now)
            if jid != "job1":
                solo.push(jid, q[lo: lo + 16], now=now)
        swept = svc.sweep_stalled(now)
        if t < stall_after + 5:
            assert swept == {}                  # not timed out yet
        svc.tick()
        solo.tick()
        sims_svc.append({jid: svc._jobs[jid].last_sims.copy()
                         for jid in svc._jobs
                         if svc._jobs[jid].last_sims is not None})
        sims_solo.append({jid: j.last_sims.copy()
                          for jid, j in solo._jobs.items()
                          if j.last_sims is not None})

    # the stalled job was evicted (slot freed, no verdict), exactly once
    assert "job1" not in svc._jobs
    assert svc.evicted_count == 1
    with pytest.raises(KeyError):
        svc.finish("job1")

    # survivors' every tick score is BIT-identical to the run that never
    # had the stalled job — before and after the eviction/compaction
    for tick_a, tick_b in zip(sims_svc, sims_solo):
        for jid in tick_b:
            np.testing.assert_array_equal(tick_a[jid], tick_b[jid])
    fin_a = svc.finish_many([j for j in queries if j != "job1"])
    fin_b = solo.finish_many([j for j in queries if j != "job1"])
    for jid in fin_b:
        assert fin_a[jid].matched == fin_b[jid].matched
        assert fin_a[jid].corr == fin_b[jid].corr


def test_sweep_returns_early_decision_of_stalled_job(paper_bank):
    p = mrsim.paper_param_sets()[0]
    q = mrsim.simulate_cpu_series("wordcount", p, dt=0.25)
    svc = TuningService(paper_bank, heartbeat_timeout=0.5, band=16,
                        threshold=0.85, margin=0.02, stable_ticks=2,
                        min_fraction=0.1, denoise=True)
    svc.submit("j", expected_len=len(q))
    early = None
    for t, lo in enumerate(range(0, len(q) // 2, 8)):
        svc.push("j", q[lo: lo + 8], now=0.1 * t)
        d = svc.tick().get("j")
        early = early or d
    assert early is not None                    # decided in flight
    swept = svc.sweep_stalled(now=100.0)        # then the agent died
    # the early decision is the only tuning signal the job produced;
    # the sweep surfaces it instead of dropping it with the slot
    assert swept == {"j": early}
    assert svc.n_active == 0


def test_straggler_flagging(paper_bank):
    svc = TuningService(paper_bank, band=16, denoise=True)
    for jid in ("steady0", "steady1", "laggard"):
        svc.submit(jid, expected_len=256)
    for t in range(20):
        for jid in ("steady0", "steady1"):
            svc.push(jid, np.full(4, 0.5, np.float32), now=0.1 * t)
        if t % 4 == 0:                          # 4x slower cadence
            svc.push("laggard", np.full(4, 0.5, np.float32), now=0.1 * t)
    assert svc.stragglers() == ["laggard"]
    # finishing the laggard removes it from the report
    svc.tick()
    svc.finish("laggard")
    assert svc.stragglers() == []


def test_elastic_controller_decision_drives_rescale(paper_bank, queries):
    """Host-only rescale path: an ElasticController shrink decision
    re-homes the device state mid-run (mesh=None -> mesh=None re-pack +
    tick recompile) without touching any score.  The sharded 8->4 device
    version of this lives in test_streaming_sharded.py."""
    ctl = ElasticController(model_parallel=1)
    base = TuningService(paper_bank, **_kw())
    resc = TuningService(paper_bank, **_kw())
    for jid, q in queries.items():
        base.submit(jid, expected_len=len(q))
        resc.submit(jid, expected_len=len(q))
    n = max(len(q) for q in queries.values())
    for t, lo in enumerate(range(0, n, 16)):
        if t == 3:
            d = ctl.decide(current_data_parallel=2,
                           alive=[0, 1], stragglers=[1])
            assert d.should_rescale and d.new_data_parallel == 1
            resc.rescale(None)
        for jid, q in queries.items():
            base.push(jid, q[lo: lo + 16])
            resc.push(jid, q[lo: lo + 16])
        base.tick()
        resc.tick()
        for jid in queries:
            np.testing.assert_array_equal(base._jobs[jid].last_sims,
                                          resc._jobs[jid].last_sims)
    assert resc.rescale_count == 1
    fin_a = base.finish_many(list(queries))
    fin_b = resc.finish_many(list(queries))
    for jid in queries:
        assert fin_a[jid].matched == fin_b[jid].matched
        assert fin_a[jid].corr == fin_b[jid].corr
