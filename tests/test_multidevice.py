"""Multi-device semantics (8 fake CPU devices in a subprocess so the main
test process keeps its single real device): MoE EP equivalence and
sharded train-step numerics vs single-device."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import ModelConfig
    from repro.models.moe import moe_init, moe_apply

    # capacity_factor=8 -> dropless: the local path computes per-expert
    # capacity from the full token set, EP shards compute it from their
    # local shard, so with tight capacity the *dropped* tokens differ by
    # design; dropless makes the two paths exactly comparable.
    cfg = ModelConfig(name="m", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64, num_experts=8,
                      top_k=2, d_ff_expert=16, capacity_factor=8.0,
                      param_dtype="float32", dtype="float32")
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

    out_local, aux_local = moe_apply(p, x, cfg)                # 1-device path

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    out_ep, aux_ep = jax.jit(
        lambda p, x: moe_apply(p, x, cfg, mesh=mesh, data_axes=("data",))
    )(p, xs)
    err = float(np.max(np.abs(np.asarray(out_ep) - np.asarray(out_local))))
    aux_err = abs(float(aux_ep) - float(aux_local))
    assert err < 2e-4, f"EP vs local mismatch {err}"
    # aux balance stats are computed per data shard then averaged, which
    # differs from global-token stats at O(1/T_local) — approximation, not
    # a bug (routing itself is exact, as the output check above proves)
    assert aux_err < 1e-2, f"aux mismatch {aux_err}"
    print("MOE_EP_OK", err, aux_err)

    # sharded vs single-device train-step numerics
    from repro.models import model as model_lib
    from repro.sharding import rules
    from repro.train.optim import AdamWConfig, adamw_init
    from repro.train.step import make_train_step
    cfg2 = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                       num_kv_heads=2, d_ff=128, vocab_size=128,
                       param_dtype="float32", dtype="float32")
    params = model_lib.init(jax.random.PRNGKey(0), cfg2)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, 128, size=(8, 32)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}

    p1, _, m1 = jax.jit(make_train_step(cfg2, rules.ExecConfig(), opt_cfg))(
        params, opt, batch)

    ex = rules.ExecConfig()
    pshape = jax.eval_shape(lambda k: model_lib.init(k, cfg2),
                            jax.random.PRNGKey(0))
    pspecs = rules.param_specs(pshape, cfg2, mesh, ex)
    shard_fn = rules.make_shard_fn(mesh, ex, 8)
    step = make_train_step(cfg2, ex, opt_cfg, mesh=mesh,
                           data_axes=("data",), shard=shard_fn)
    params_sh = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P)))
    bspecs = rules.batch_specs(batch, mesh)
    batch_sh = jax.device_put(batch, jax.tree.map(
        lambda s: NamedSharding(mesh, s), bspecs,
        is_leaf=lambda x: isinstance(x, P)))
    p2, _, m2 = jax.jit(step)(params_sh, opt, batch_sh)
    dl = abs(float(m1["loss"]) - float(m2["loss"]))
    assert dl < 1e-4, f"loss mismatch {dl}"
    diffs = jax.tree.map(lambda a, b: float(np.max(np.abs(
        np.asarray(a) - np.asarray(b)))), p1, p2)
    md = max(jax.tree.leaves(diffs))
    assert md < 1e-4, f"param mismatch {md}"
    print("SHARDED_STEP_OK", dl, md)
""")


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], cwd=os.path.join(
        os.path.dirname(__file__), ".."), env=env, capture_output=True,
        text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "MOE_EP_OK" in r.stdout and "SHARDED_STEP_OK" in r.stdout
