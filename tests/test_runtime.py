"""Fault-tolerance runtime: heartbeats, stragglers, elastic rescale."""
from repro.runtime import (ElasticController, HeartbeatTracker,
                           StragglerDetector)


def test_heartbeat_timeout():
    hb = HeartbeatTracker(timeout=10.0)
    hb.beat(0, 1, now=0.0)
    hb.beat(1, 1, now=0.0)
    hb.beat(0, 2, now=8.0)
    assert hb.sweep(now=11.0) == [1]
    assert hb.alive_workers() == [0]
    # worker returns
    hb.beat(1, 3, now=12.0)
    assert hb.alive_workers() == [0, 1]


def test_heartbeat_backwards_beat_cannot_rewind():
    """A beat stamped by a backwards-jumping clock (NTP step, VM
    migration) proves liveness; it must never rewind ``last_time`` so a
    later honest sweep times the worker out on the skewed stamp."""
    hb = HeartbeatTracker(timeout=10.0)
    hb.beat(0, 1, now=100.0)
    hb.beat(0, 2, now=3.0)          # clock jumped back 97s
    assert hb.sweep(now=105.0) == []
    assert hb.alive_workers() == [0]
    # step counters are monotone under the same skew
    assert hb.workers[0].last_step == 2
    assert hb.workers[0].last_time == 100.0


def test_heartbeat_backwards_sweep_is_clamped():
    """``sweep(t); sweep(t - skew)`` decides exactly what ``sweep(t)``
    alone would: the sweep clock is clamped to its high-water mark, so
    a skewed monitor can neither evict nor resurrect."""
    hb = HeartbeatTracker(timeout=10.0)
    hb.beat(0, 1, now=0.0)
    hb.beat(1, 1, now=20.0)
    assert hb.sweep(now=25.0) == [0]
    # backwards sweep: must not re-evaluate at the earlier time (worker
    # 1 would look alive-forever, worker 0 freshly dead again)
    assert hb.sweep(now=5.0) == []
    assert hb.alive_workers() == [1]
    # and a backwards sweep before any eviction evicts nobody
    hb2 = HeartbeatTracker(timeout=10.0)
    hb2.beat(0, 1, now=50.0)
    hb2.sweep(now=51.0)
    assert hb2.sweep(now=-1000.0) == []
    assert hb2.alive_workers() == [0]


def test_straggler_detection():
    sd = StragglerDetector(window=8, factor=1.5, min_samples=4)
    for step in range(8):
        for w in range(4):
            sd.record(w, 1.0 if w != 2 else 2.5)
    assert sd.stragglers() == [2]


def test_straggler_needs_samples():
    sd = StragglerDetector(min_samples=4)
    sd.record(0, 1.0)
    assert sd.stragglers() == []


def test_elastic_stable():
    ec = ElasticController(model_parallel=16)
    d = ec.decide(16, alive=list(range(16)))
    assert not d.should_rescale


def test_elastic_shrinks_on_failure():
    ec = ElasticController(model_parallel=16)
    d = ec.decide(16, alive=list(range(13)), stragglers=[3])
    assert d.should_rescale
    assert d.new_data_parallel == 8
    assert "shrink" in d.reason


def test_elastic_grows_back():
    ec = ElasticController(model_parallel=16)
    d = ec.decide(8, alive=list(range(16)))
    assert d.should_rescale and d.new_data_parallel == 16
