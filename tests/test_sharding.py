"""Sharding rules: spec trees mirror params, divisibility guards hold."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs as cfglib
from repro.models import model as model_lib
from repro.sharding import rules


class FakeMesh:
    """Duck-typed mesh (shape dict only) — rules only read .shape."""
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", cfglib.ARCHS)
def test_param_specs_structure_and_divisibility(arch):
    cfg = cfglib.get(arch)
    pshape = jax.eval_shape(lambda k: model_lib.init(k, cfg),
                            jax.random.PRNGKey(0))
    specs = rules.param_specs(pshape, cfg, MESH, rules.ExecConfig(fsdp=True))
    # same tree structure
    assert (jax.tree.structure(pshape) ==
            jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)))

    def check(leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 9):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([MESH.shape[a] for a in axes]))
            assert dim % size == 0, (leaf.shape, spec)
    jax.tree.map(check, pshape, specs,
                 is_leaf=lambda x: isinstance(x, P))


def test_batch_specs_shard_when_divisible():
    import jax.numpy as jnp
    batch = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = rules.batch_specs(batch, MESH)
    assert specs["tokens"] == P(("data",), None)
    assert specs["pos"] == P()


def test_batch_specs_replicate_when_not_divisible():
    import jax.numpy as jnp
    batch = {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}
    specs = rules.batch_specs(batch, MESH)
    assert specs["tokens"] == P(None, None)


def test_cache_specs_seq_sharding_for_batch1():
    import jax.numpy as jnp
    cfg = cfglib.get("zamba2-7b")
    cache = model_lib.make_cache(cfg, 1, 1024 * 16)
    specs = rules.cache_specs(cache, cfg, MESH, batch=1)
    kv = specs["segments"][0]["5_shared_attn"]
    # [L, B, S, KV, dh]: batch=1 not shardable -> seq over data
    assert kv["k"][2] in ("data", ("data",))


def test_opt_specs_zero1_adds_data_axis():
    cfg = cfglib.get("phi3-mini-3p8b")
    pshape = jax.eval_shape(lambda k: model_lib.init(k, cfg),
                            jax.random.PRNGKey(0))
    pspecs = rules.param_specs(pshape, cfg, MESH, rules.ExecConfig())
    ospecs = rules.opt_state_specs(pshape, pspecs, MESH,
                                   rules.ExecConfig(zero1=True))
    # at least one leaf gained a data-axis shard not present in pspec
    got = []
    jax.tree.map(lambda ps, os_: got.append(ps != os_), pspecs, ospecs,
                 is_leaf=lambda x: isinstance(x, P))
    assert any(got)
