import os
import sys

# NOTE: no XLA_FLAGS here on purpose — tests must see the single real CPU
# device; only launch/dryrun.py (run as a subprocess) forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
