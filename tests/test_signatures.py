"""Jaxpr-based utilization signatures."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import signatures


def test_dot_flops_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    costs = signatures.jaxpr_costs(jax.make_jaxpr(f)(a, b).jaxpr)
    dot = [c for c in costs if c.name == "dot_general"]
    assert len(dot) == 1
    assert dot[0].flops == 2 * 64 * 128 * 32


def test_scan_expansion_scales_costs():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        c, _ = jax.lax.scan(body, x, ws)
        return c
    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)
    costs = signatures.jaxpr_costs(jax.make_jaxpr(f)(x, ws).jaxpr)
    total_dot = sum(c.flops for c in costs if c.name == "dot_general")
    assert total_dot == 5 * 2 * 8 * 16 * 16


def test_signature_deterministic_and_shaped():
    def f(a, b):
        return jnp.sum(jnp.tanh(a @ b))
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    s1 = signatures.signature_of(f, a, b, samples=128)
    s2 = signatures.signature_of(f, a, b, samples=128)
    assert s1.shape == (128,)
    np.testing.assert_array_equal(s1, s2)
    assert (s1 >= 0).all() and (s1 <= 1 + 1e-6).all()


def test_different_programs_different_signatures():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    s_mm = signatures.signature_of(lambda x: x @ x, a, samples=64)
    s_el = signatures.signature_of(lambda x: jnp.tanh(x) * 2, a, samples=64)
    assert not np.allclose(s_mm, s_el)
