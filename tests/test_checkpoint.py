"""Checkpoint: atomic roundtrip, hash verify, gc, exact-resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t, {"note": "hi"})
    restored, manifest = restore_checkpoint(str(tmp_path), t)
    assert manifest["step"] == 3 and manifest["metadata"]["note"] == "hi"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_latest_pointer_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4
    _, manifest = mgr.restore(t)
    assert manifest["step"] == 4


def test_corruption_detected(tmp_path):
    t = _tree()
    path = save_checkpoint(str(tmp_path), 1, t)
    npz = os.path.join(path, "arrays.npz")
    data = dict(np.load(npz))
    data["leaf_00000"] = data["leaf_00000"] + 1
    np.savez(npz, **data)
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), t)


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((9, 4)), "b": {"c": jnp.zeros(6, jnp.int32)}}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_elastic_restore_onto_mesh(tmp_path):
    """Restore places leaves onto a (new) mesh + spec tree."""
    from jax.sharding import PartitionSpec as P
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    specs = {"a": P(None, None), "b": {"c": P()}}
    restored, _ = restore_checkpoint(str(tmp_path), t, mesh=mesh, specs=specs)
    assert restored["a"].sharding.mesh.shape["data"] == 1
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_exact_resume_equivalence(tmp_path):
    """train 6 steps == train 3, checkpoint, restore, train 3 more."""
    from repro.models import ModelConfig, model
    from repro.train.optim import AdamWConfig, adamw_init
    from repro.train.step import make_train_step
    from repro.sharding.rules import ExecConfig
    from repro.data import DataPipeline, SyntheticCorpus

    cfg = ModelConfig(name="t", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64,
                      param_dtype="float32", dtype="float32")
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, ExecConfig(), opt_cfg))
    pipe = DataPipeline(SyntheticCorpus(64), seq_len=16, global_batch=2)

    def run(params, opt, s0, s1):
        for s in range(s0, s1):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            params, opt, _ = step(params, opt, batch)
        return params, opt

    pA, oA = run(params, opt, 0, 6)
    pB, oB = run(params, opt, 0, 3)
    save_checkpoint(str(tmp_path), 3, (pB, oB))
    (pB, oB), _ = restore_checkpoint(str(tmp_path), (pB, oB))
    pB, oB = run(pB, oB, 3, 6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), pA, pB)
