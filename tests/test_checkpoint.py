"""Checkpoint: atomic roundtrip, hash verify, gc, exact-resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_checkpoint, restore_checkpoint


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 3, t, {"note": "hi"})
    restored, manifest = restore_checkpoint(str(tmp_path), t)
    assert manifest["step"] == 3 and manifest["metadata"]["note"] == "hi"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_latest_pointer_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4
    _, manifest = mgr.restore(t)
    assert manifest["step"] == 4


def test_torn_step_dir_is_invisible(tmp_path):
    """A step dir without a manifest (interrupted two-phase writer) is
    never listed, never latest, never restored — even when the LATEST
    pointer names it."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree()
    mgr.save(5, t)
    torn = tmp_path / "step_000009"
    torn.mkdir()
    np.savez(torn / "arrays.npz", leaf_00000=np.zeros(3))  # no manifest
    (tmp_path / "LATEST").write_text("step_000009")

    assert mgr.steps() == [5]
    assert mgr.latest_step() == 5
    _, manifest = mgr.restore(t)
    assert manifest["step"] == 5


def test_gc_sweeps_torn_artifacts(tmp_path):
    """save() garbage-collects interrupted writers' leftovers: orphaned
    two-phase tmp dirs and manifest-less step dirs."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    torn = tmp_path / "step_000002"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"partial")
    orphan = tmp_path / ".tmp_ckpt_dead"
    orphan.mkdir()
    (orphan / "arrays.npz").write_bytes(b"partial")

    mgr.save(7, _tree())
    assert not torn.exists()
    assert not orphan.exists()
    assert mgr.steps() == [7]


def test_load_checkpoint_tree_target_free(tmp_path):
    """Dict-nested checkpoints restore WITHOUT a shape-matching target
    (the crash-recovery path); non-dict trees refuse."""
    from repro.checkpoint import load_checkpoint_tree
    t = {"x": np.arange(5, dtype=np.float32), "sub": {"y": np.eye(3)}}
    save_checkpoint(str(tmp_path), 2, t, {"tag": "wal"})
    tree, manifest = load_checkpoint_tree(str(tmp_path))
    assert manifest["metadata"]["tag"] == "wal"
    np.testing.assert_array_equal(tree["x"], t["x"])
    np.testing.assert_array_equal(tree["sub"]["y"], t["sub"]["y"])

    save_checkpoint(str(tmp_path / "tup"), 1, (np.zeros(2), np.ones(2)))
    with pytest.raises(ValueError):
        load_checkpoint_tree(str(tmp_path / "tup"))


def test_corruption_detected(tmp_path):
    t = _tree()
    path = save_checkpoint(str(tmp_path), 1, t)
    npz = os.path.join(path, "arrays.npz")
    data = dict(np.load(npz))
    data["leaf_00000"] = data["leaf_00000"] + 1
    np.savez(npz, **data)
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), t)


def test_shape_mismatch_rejected(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    bad = {"a": jnp.zeros((9, 4)), "b": {"c": jnp.zeros(6, jnp.int32)}}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_elastic_restore_onto_mesh(tmp_path):
    """Restore places leaves onto a (new) mesh + spec tree."""
    from jax.sharding import PartitionSpec as P
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    specs = {"a": P(None, None), "b": {"c": P()}}
    restored, _ = restore_checkpoint(str(tmp_path), t, mesh=mesh, specs=specs)
    assert restored["a"].sharding.mesh.shape["data"] == 1
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_exact_resume_equivalence(tmp_path):
    """train 6 steps == train 3, checkpoint, restore, train 3 more."""
    from repro.models import ModelConfig, model
    from repro.train.optim import AdamWConfig, adamw_init
    from repro.train.step import make_train_step
    from repro.sharding.rules import ExecConfig
    from repro.data import DataPipeline, SyntheticCorpus

    cfg = ModelConfig(name="t", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64,
                      param_dtype="float32", dtype="float32")
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, ExecConfig(), opt_cfg))
    pipe = DataPipeline(SyntheticCorpus(64), seq_len=16, global_batch=2)

    def run(params, opt, s0, s1):
        for s in range(s0, s1):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()}
            params, opt, _ = step(params, opt, batch)
        return params, opt

    pA, oA = run(params, opt, 0, 6)
    pB, oB = run(params, opt, 0, 3)
    save_checkpoint(str(tmp_path), 3, (pB, oB))
    (pB, oB), _ = restore_checkpoint(str(tmp_path), (pB, oB))
    pB, oB = run(pB, oB, 3, 6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6), pA, pB)
