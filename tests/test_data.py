"""Data pipeline: determinism, shard partition, resume."""
import numpy as np
import pytest

from repro.data import DataPipeline, SyntheticCorpus


def test_determinism():
    pipe = DataPipeline(SyntheticCorpus(1000, seed=1), 32, 8)
    a = pipe.batch_at(5)
    b = pipe.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_labels_are_shifted_tokens():
    pipe = DataPipeline(SyntheticCorpus(1000), 32, 4)
    b = pipe.batch_at(0)
    # labels[t] == tokens[t+1] by construction of the same underlying seq
    assert b["tokens"].shape == b["labels"].shape == (4, 32)


def test_worker_shards_partition_global_batch():
    corpus = SyntheticCorpus(1000, seed=2)
    full = DataPipeline(corpus, 16, 8, dp_rank=0, dp_size=1).batch_at(3)
    parts = [DataPipeline(corpus, 16, 8, dp_rank=r, dp_size=4).batch_at(3)
             for r in range(4)]
    stacked = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(full["tokens"], stacked)


def test_resume_from_state():
    pipe = DataPipeline(SyntheticCorpus(1000), 16, 4)
    state = pipe.state_dict(7)
    assert DataPipeline.resume_step(state) == 7
    np.testing.assert_array_equal(pipe.batch_at(7)["tokens"],
                                  pipe.batch_at(7)["tokens"])


def test_bad_dp_size_rejected():
    with pytest.raises(ValueError):
        DataPipeline(SyntheticCorpus(10), 16, global_batch=6, dp_size=4)


def test_codebook_corpus_shape():
    pipe = DataPipeline(SyntheticCorpus(100, num_codebooks=4), 16, 2)
    b = pipe.batch_at(0)
    assert b["tokens"].shape == (2, 16, 4)
