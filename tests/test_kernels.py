"""Per-kernel shape/dtype sweeps vs ref.py oracles (interpret mode)."""
import numpy as np
import pytest

from repro.kernels.dtw import dtw_batched, dtw_matrix_ref
from repro.kernels.iir import lfilter_batched, lfilter_ref
from repro.kernels.attention import flash_attention, attention_ref
from repro.kernels.gla import gla_scan, gla_ref
from repro.core.filters import cheby1_design


@pytest.mark.parametrize("n,m,k", [(16, 16, 1), (33, 57, 3), (64, 40, 2),
                                   (8, 128, 4)])
def test_dtw_kernel_vs_ref(n, m, k):
    rng = np.random.default_rng(n * m + k)
    x = rng.normal(size=n).astype(np.float32)
    ys = rng.normal(size=(k, m)).astype(np.float32)
    D = np.asarray(dtw_batched(x, ys))
    for i in range(k):
        np.testing.assert_allclose(D[i], dtw_matrix_ref(x, ys[i]),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("order,cutoff,B,T", [(6, 0.125, 3, 100),
                                              (4, 0.3, 130, 64),
                                              (2, 0.5, 1, 257)])
def test_iir_kernel_vs_ref(order, cutoff, B, T):
    b, a = cheby1_design(order, 1.0, cutoff)
    x = np.random.default_rng(B * T).normal(size=(B, T)).astype(np.float32)
    y = np.asarray(lfilter_batched(b, a, x))
    yr = lfilter_ref(b, a, x)
    np.testing.assert_allclose(y, yr, atol=5e-3)


@pytest.mark.parametrize("B,H,KV,S,dh,bq,bk,dtype", [
    (1, 2, 2, 128, 32, 64, 64, np.float32),
    (2, 4, 2, 256, 32, 128, 128, np.float32),
    (1, 8, 1, 128, 64, 32, 64, np.float32),
    (2, 4, 4, 128, 16, 64, 32, "bfloat16"),
])
def test_flash_attention_vs_ref(B, H, KV, S, dh, bq, bk, dtype):
    import jax.numpy as jnp
    rng = np.random.default_rng(S + H)
    q = rng.normal(size=(B, H, S, dh)).astype(np.float32)
    k = rng.normal(size=(B, KV, S, dh)).astype(np.float32)
    v = rng.normal(size=(B, KV, S, dh)).astype(np.float32)
    if dtype == "bfloat16":
        q, k, v = (jnp.asarray(t, jnp.bfloat16) for t in (q, k, v))
        tol = 5e-2
    else:
        tol = 1e-5
    o = np.asarray(flash_attention(q, k, v, bq=bq, bk=bk),
                   np.float32)
    r = attention_ref(np.asarray(q, np.float32), np.asarray(k, np.float32),
                      np.asarray(v, np.float32))
    np.testing.assert_allclose(o, r, rtol=tol, atol=tol)


def test_flash_attention_non_causal():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(1, 2, 64, 16)).astype(np.float32)
    k = rng.normal(size=(1, 2, 64, 16)).astype(np.float32)
    v = rng.normal(size=(1, 2, 64, 16)).astype(np.float32)
    o = np.asarray(flash_attention(q, k, v, bq=32, bk=32, causal=False))
    r = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(o, r, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,H,S,dk,dv,chunk", [
    (1, 2, 32, 8, 8, 8), (2, 3, 64, 16, 8, 16), (1, 1, 128, 64, 64, 32),
])
def test_gla_kernel_vs_ref(B, H, S, dk, dv, chunk):
    rng = np.random.default_rng(S + dk)
    q = rng.normal(size=(B, H, S, dk)).astype(np.float32)
    k = (rng.normal(size=(B, H, S, dk)) * 0.3).astype(np.float32)
    v = rng.normal(size=(B, H, S, dv)).astype(np.float32)
    log_a = -np.abs(rng.normal(size=(B, H, S)) * 0.2).astype(np.float32)
    o, s = gla_scan(q, k, v, log_a, chunk=chunk)
    orf, srf = gla_ref(q, k, v, log_a)
    np.testing.assert_allclose(np.asarray(o), orf, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), srf, rtol=1e-3, atol=1e-4)


def test_gla_kernel_matches_model_path():
    """Kernel and the jnp gla_chunked used by the models agree."""
    import jax.numpy as jnp
    from repro.models.ssm import gla_chunked
    rng = np.random.default_rng(7)
    q = rng.normal(size=(1, 2, 64, 8)).astype(np.float32)
    k = rng.normal(size=(1, 2, 64, 8)).astype(np.float32)
    v = rng.normal(size=(1, 2, 64, 4)).astype(np.float32)
    log_a = -np.abs(rng.normal(size=(1, 2, 64)) * 0.1).astype(np.float32)
    o1, s1 = gla_scan(q, k, v, log_a, chunk=16)
    o2, s2 = gla_chunked(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         jnp.asarray(log_a), 16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-5)


def _dyadic_series(rng, n, denom=8, hi=9):
    """Series on a coarse dyadic grid: every DTW cost, path sum and
    correlation-moment sum is exactly representable in f32, so the scan,
    wavefront and Pallas formulations produce BIT-IDENTICAL cells and the
    warp-path predecessor argmin has no float-noise tie ambiguity —
    cell-by-cell equivalence can be asserted exactly instead of modulo
    tie-flip propagation."""
    return (rng.integers(0, hi, n) / float(denom)).astype(np.float32)


@pytest.mark.parametrize("band,block_k", [(None, 128), (6, 128), (None, 4),
                                          (6, 4)])
def test_stream_scored_kernel_cell_by_cell(band, block_k):
    """Moment-carrying Pallas streaming kernel == the jnp scored wavefront
    (`bank_extend_tick_scored`) AND the row-formulation reference
    (`_bank_extend_many`) on every cell — DP rows, (sy, syy, sxy) moment
    slabs and open-end scores alike — across ragged reference banks,
    Sakoe-Chiba bands, ragged per-job chunks (including empty ones) and a
    block_k that forces reference-tile padding.  Exact comparison on
    dyadic-grid data (see `_dyadic_series`)."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw
    from repro.core.database import pack_series

    rng = np.random.default_rng(11 if band is None else band + block_k)
    series = [_dyadic_series(rng, int(rng.integers(12, 30)))
              for _ in range(7)]
    bank = pack_series(series)
    k, m = bank.series.shape
    J, C = 3, 8
    qlens = jnp.full((J,), 4 * C, jnp.int32)
    bank_t = jnp.asarray(bank.series.T)
    lengths = jnp.asarray(bank.lengths)
    rows_w = jnp.full((J, m, k), _dtw._INF)
    moms_w = jnp.zeros((3, J, m, k))
    ns_w = jnp.zeros((J,), jnp.int32)
    sx_w = jnp.zeros((J,))
    sxx_w = jnp.zeros((J,))
    rows_p, moms_p, ns_p, sx_p, sxx_p = rows_w, moms_w, ns_w, sx_w, sxx_w
    rows_h = jnp.full((J, k, m), _dtw._INF)
    ns_h = jnp.zeros((J,), jnp.int32)
    for _ in range(4):
        nv = jnp.asarray(rng.integers(0, C + 1, size=J).astype(np.int32))
        ch = jnp.asarray((rng.integers(0, 9, (J, C)) / 8.0)
                         .astype(np.float32))
        rows_w, moms_w, ns_w, sx_w, sxx_w, sc_w = \
            _dtw.bank_extend_tick_scored(
                rows_w, moms_w, ns_w, sx_w, sxx_w, bank_t, lengths, ch,
                nv, qlens, band=band)
        rows_p, moms_p, ns_p, sx_p, sxx_p, sc_p = \
            _dtw.bank_extend_tick_scored_dispatch(
                rows_p, moms_p, ns_p, sx_p, sxx_p, bank_t, lengths,
                ch, nv, qlens, band=band, use_kernel=True,
                interpret=True, block_k=block_k)
        rows_h, ns_h, _ = _dtw._bank_extend_many(
            rows_h, ns_h, jnp.asarray(bank.series), lengths, ch, nv,
            qlens, band, False)
        rp, rw = np.asarray(rows_p), np.asarray(rows_w)
        rh = np.asarray(rows_h).transpose(0, 2, 1)
        finite = rw < 1e37
        assert (finite == (rp < 1e37)).all()
        assert (finite == (rh < 1e37)).all()
        np.testing.assert_array_equal(rp[finite], rw[finite])
        np.testing.assert_array_equal(rp[finite], rh[finite])
        mp, mw = np.asarray(moms_p), np.asarray(moms_w)
        fin3 = np.broadcast_to(finite[None], mp.shape)
        np.testing.assert_array_equal(mp[fin3], mw[fin3])
        np.testing.assert_array_equal(np.asarray(sc_p), np.asarray(sc_w))
    np.testing.assert_array_equal(np.asarray(ns_p), np.asarray(ns_w))
    np.testing.assert_array_equal(np.asarray(ns_p), np.asarray(ns_h))


def test_stream_scored_kernel_scores_match_host_backtrack():
    """Through the Pallas path, the fused on-device scores still reproduce
    the host backtrack scorer on smooth real-valued data (tolerance-level:
    float noise can flip warp-path ties, which moves individual moments
    but not scores)."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw
    from repro.core.database import pack_series
    from repro.core.similarity import prefix_similarity_bank

    rng = np.random.default_rng(5)
    series = []
    for i in range(5):
        l = int(rng.integers(16, 40))
        t = np.linspace(0, 1, l, dtype=np.float32)
        series.append(np.clip(
            0.5 + 0.3 * np.sin(2 * np.pi * (1.5 + i) * t)
            + 0.05 * rng.normal(size=l), 0, 1).astype(np.float32))
    bank = pack_series(series)
    k, m = bank.series.shape
    J, C, nticks = 2, 8, 4
    qlen = nticks * C
    qs = np.stack([np.clip(
        0.5 + 0.3 * np.sin(2 * np.pi * (2 + j) * np.linspace(0, 1, qlen))
        + 0.05 * rng.normal(size=qlen), 0, 1).astype(np.float32)
        for j in range(J)])
    rows = jnp.full((J, m, k), _dtw._INF)
    moms = jnp.zeros((3, J, m, k))
    ns = jnp.zeros((J,), jnp.int32)
    sx = jnp.zeros((J,))
    sxx = jnp.zeros((J,))
    qlens = jnp.full((J,), qlen, jnp.int32)
    rows_h = jnp.full((J, k, m), _dtw._INF)
    ns_h = jnp.zeros((J,), jnp.int32)
    collected = []
    for t0 in range(nticks):
        ch = jnp.asarray(qs[:, t0 * C:(t0 + 1) * C])
        nv = jnp.full((J,), C, jnp.int32)
        rows, moms, ns, sx, sxx, scores = \
            _dtw.bank_extend_tick_scored_dispatch(
                rows, moms, ns, sx, sxx, jnp.asarray(bank.series.T),
                jnp.asarray(bank.lengths), ch, nv, qlens,
                use_kernel=True, interpret=True)
        rows_h, ns_h, coll = _dtw._bank_extend_many(
            rows_h, ns_h, jnp.asarray(bank.series),
            jnp.asarray(bank.lengths), ch, nv, qlens, None, True)
        collected.append(np.asarray(coll))
        stack = np.concatenate(collected)
        dev = np.asarray(scores)
        for j in range(J):
            host = prefix_similarity_bank(qs[j, :(t0 + 1) * C], bank,
                                          stack[:, j])
            np.testing.assert_allclose(dev[j], host, atol=2e-3)


@pytest.mark.parametrize("band,block_k", [(None, 128), (6, 128), (None, 4)])
def test_stream_kernel_cell_by_cell_vs_bank_extend(band, block_k):
    """Pallas streaming bank-extend == core.dtw._bank_extend_many on every
    cell, across multiple ragged chunks (random per-job nvalid), ragged
    reference lengths, banded and unbanded, including a block_k that
    forces reference-tile padding."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw
    from repro.core.database import pack_series
    from repro.kernels.dtw import stream_bank_extend_kernel

    rng = np.random.default_rng(0 if band is None else band)
    series = [rng.random(int(rng.integers(12, 30))).astype(np.float32)
              for _ in range(7)]
    bank = pack_series(series)
    k, m = bank.series.shape
    J, C = 3, 8
    rows_p = jnp.full((J, k, m), _dtw._INF)
    ns_p = jnp.zeros((J,), jnp.int32)
    rows_h, ns_h = rows_p, ns_p
    qlens = jnp.full((J,), 4 * C, jnp.int32)
    for _ in range(4):
        nv = jnp.asarray(rng.integers(0, C + 1, size=J).astype(np.int32))
        ch = jnp.asarray(rng.random((J, C)).astype(np.float32))
        rows_p, ns_p = stream_bank_extend_kernel(
            rows_p, ns_p, bank.series, bank.lengths, ch, nv, qlens,
            band=band, block_k=block_k, interpret=True)
        rows_h, ns_h, _ = _dtw._bank_extend_many(
            rows_h, ns_h, jnp.asarray(bank.series),
            jnp.asarray(bank.lengths), ch, nv, qlens, band, False)
    r1, r2 = np.asarray(rows_p), np.asarray(rows_h)
    finite = r2 < 1e37
    assert (finite == (r1 < 1e37)).all()
    np.testing.assert_allclose(r1[finite], r2[finite], rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ns_p), np.asarray(ns_h))
