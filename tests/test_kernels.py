"""Per-kernel shape/dtype sweeps vs ref.py oracles (interpret mode)."""
import numpy as np
import pytest

from repro.kernels.dtw import dtw_batched, dtw_matrix_ref
from repro.kernels.iir import lfilter_batched, lfilter_ref
from repro.kernels.attention import flash_attention, attention_ref
from repro.kernels.gla import gla_scan, gla_ref
from repro.core.filters import cheby1_design


@pytest.mark.parametrize("n,m,k", [(16, 16, 1), (33, 57, 3), (64, 40, 2),
                                   (8, 128, 4)])
def test_dtw_kernel_vs_ref(n, m, k):
    rng = np.random.default_rng(n * m + k)
    x = rng.normal(size=n).astype(np.float32)
    ys = rng.normal(size=(k, m)).astype(np.float32)
    D = np.asarray(dtw_batched(x, ys))
    for i in range(k):
        np.testing.assert_allclose(D[i], dtw_matrix_ref(x, ys[i]),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("order,cutoff,B,T", [(6, 0.125, 3, 100),
                                              (4, 0.3, 130, 64),
                                              (2, 0.5, 1, 257)])
def test_iir_kernel_vs_ref(order, cutoff, B, T):
    b, a = cheby1_design(order, 1.0, cutoff)
    x = np.random.default_rng(B * T).normal(size=(B, T)).astype(np.float32)
    y = np.asarray(lfilter_batched(b, a, x))
    yr = lfilter_ref(b, a, x)
    np.testing.assert_allclose(y, yr, atol=5e-3)


@pytest.mark.parametrize("B,H,KV,S,dh,bq,bk,dtype", [
    (1, 2, 2, 128, 32, 64, 64, np.float32),
    (2, 4, 2, 256, 32, 128, 128, np.float32),
    (1, 8, 1, 128, 64, 32, 64, np.float32),
    (2, 4, 4, 128, 16, 64, 32, "bfloat16"),
])
def test_flash_attention_vs_ref(B, H, KV, S, dh, bq, bk, dtype):
    import jax.numpy as jnp
    rng = np.random.default_rng(S + H)
    q = rng.normal(size=(B, H, S, dh)).astype(np.float32)
    k = rng.normal(size=(B, KV, S, dh)).astype(np.float32)
    v = rng.normal(size=(B, KV, S, dh)).astype(np.float32)
    if dtype == "bfloat16":
        q, k, v = (jnp.asarray(t, jnp.bfloat16) for t in (q, k, v))
        tol = 5e-2
    else:
        tol = 1e-5
    o = np.asarray(flash_attention(q, k, v, bq=bq, bk=bk),
                   np.float32)
    r = attention_ref(np.asarray(q, np.float32), np.asarray(k, np.float32),
                      np.asarray(v, np.float32))
    np.testing.assert_allclose(o, r, rtol=tol, atol=tol)


def test_flash_attention_non_causal():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(1, 2, 64, 16)).astype(np.float32)
    k = rng.normal(size=(1, 2, 64, 16)).astype(np.float32)
    v = rng.normal(size=(1, 2, 64, 16)).astype(np.float32)
    o = np.asarray(flash_attention(q, k, v, bq=32, bk=32, causal=False))
    r = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(o, r, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,H,S,dk,dv,chunk", [
    (1, 2, 32, 8, 8, 8), (2, 3, 64, 16, 8, 16), (1, 1, 128, 64, 64, 32),
])
def test_gla_kernel_vs_ref(B, H, S, dk, dv, chunk):
    rng = np.random.default_rng(S + dk)
    q = rng.normal(size=(B, H, S, dk)).astype(np.float32)
    k = (rng.normal(size=(B, H, S, dk)) * 0.3).astype(np.float32)
    v = rng.normal(size=(B, H, S, dv)).astype(np.float32)
    log_a = -np.abs(rng.normal(size=(B, H, S)) * 0.2).astype(np.float32)
    o, s = gla_scan(q, k, v, log_a, chunk=chunk)
    orf, srf = gla_ref(q, k, v, log_a)
    np.testing.assert_allclose(np.asarray(o), orf, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), srf, rtol=1e-3, atol=1e-4)


def test_gla_kernel_matches_model_path():
    """Kernel and the jnp gla_chunked used by the models agree."""
    import jax.numpy as jnp
    from repro.models.ssm import gla_chunked
    rng = np.random.default_rng(7)
    q = rng.normal(size=(1, 2, 64, 8)).astype(np.float32)
    k = rng.normal(size=(1, 2, 64, 8)).astype(np.float32)
    v = rng.normal(size=(1, 2, 64, 4)).astype(np.float32)
    log_a = -np.abs(rng.normal(size=(1, 2, 64)) * 0.1).astype(np.float32)
    o1, s1 = gla_scan(q, k, v, log_a, chunk=16)
    o2, s2 = gla_chunked(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                         jnp.asarray(log_a), 16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-5)


def _dyadic_series(rng, n, denom=8, hi=9):
    """Series on a coarse dyadic grid: every DTW cost, path sum and
    correlation-moment sum is exactly representable in f32, so the scan,
    wavefront and Pallas formulations produce BIT-IDENTICAL cells and the
    warp-path predecessor argmin has no float-noise tie ambiguity —
    cell-by-cell equivalence can be asserted exactly instead of modulo
    tie-flip propagation."""
    return (rng.integers(0, hi, n) / float(denom)).astype(np.float32)


@pytest.mark.parametrize("band,block_k", [(None, 128), (6, 128), (None, 4),
                                          (6, 4)])
def test_stream_scored_kernel_cell_by_cell(band, block_k):
    """Moment-carrying Pallas streaming kernel == the jnp scored wavefront
    (`bank_extend_tick_scored`) AND the row-formulation reference
    (`_bank_extend_many`) on every cell — DP rows, (sy, syy, sxy) moment
    slabs and open-end scores alike — across ragged reference banks,
    Sakoe-Chiba bands, ragged per-job chunks (including empty ones) and a
    block_k that forces reference-tile padding.  Exact comparison on
    dyadic-grid data (see `_dyadic_series`)."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw
    from repro.core.database import pack_series

    rng = np.random.default_rng(11 if band is None else band + block_k)
    series = [_dyadic_series(rng, int(rng.integers(12, 30)))
              for _ in range(7)]
    bank = pack_series(series)
    k, m = bank.series.shape
    J, C = 3, 8
    qlens = jnp.full((J,), 4 * C, jnp.int32)
    bank_t = jnp.asarray(bank.series.T)
    lengths = jnp.asarray(bank.lengths)
    rows_w = jnp.full((J, m, k), _dtw._INF)
    moms_w = jnp.zeros((3, J, m, k))
    ns_w = jnp.zeros((J,), jnp.int32)
    sx_w = jnp.zeros((J,))
    sxx_w = jnp.zeros((J,))
    rows_p, moms_p, ns_p, sx_p, sxx_p = rows_w, moms_w, ns_w, sx_w, sxx_w
    rows_h = jnp.full((J, k, m), _dtw._INF)
    ns_h = jnp.zeros((J,), jnp.int32)
    for _ in range(4):
        nv = jnp.asarray(rng.integers(0, C + 1, size=J).astype(np.int32))
        ch = jnp.asarray((rng.integers(0, 9, (J, C)) / 8.0)
                         .astype(np.float32))
        rows_w, moms_w, ns_w, sx_w, sxx_w, sc_w = \
            _dtw.bank_extend_tick_scored(
                rows_w, moms_w, ns_w, sx_w, sxx_w, bank_t, lengths, ch,
                nv, qlens, band=band)
        rows_p, moms_p, ns_p, sx_p, sxx_p, sc_p = \
            _dtw.bank_extend_tick_scored_dispatch(
                rows_p, moms_p, ns_p, sx_p, sxx_p, bank_t, lengths,
                ch, nv, qlens, band=band, use_kernel=True,
                interpret=True, block_k=block_k)
        rows_h, ns_h, _ = _dtw._bank_extend_many(
            rows_h, ns_h, jnp.asarray(bank.series), lengths, ch, nv,
            qlens, band, False)
        rp, rw = np.asarray(rows_p), np.asarray(rows_w)
        rh = np.asarray(rows_h).transpose(0, 2, 1)
        finite = rw < 1e37
        assert (finite == (rp < 1e37)).all()
        assert (finite == (rh < 1e37)).all()
        np.testing.assert_array_equal(rp[finite], rw[finite])
        np.testing.assert_array_equal(rp[finite], rh[finite])
        mp, mw = np.asarray(moms_p), np.asarray(moms_w)
        fin3 = np.broadcast_to(finite[None], mp.shape)
        np.testing.assert_array_equal(mp[fin3], mw[fin3])
        np.testing.assert_array_equal(np.asarray(sc_p), np.asarray(sc_w))
    np.testing.assert_array_equal(np.asarray(ns_p), np.asarray(ns_w))
    np.testing.assert_array_equal(np.asarray(ns_p), np.asarray(ns_h))


def test_stream_scored_kernel_scores_match_host_backtrack():
    """Through the Pallas path, the fused on-device scores still reproduce
    the host backtrack scorer on smooth real-valued data (tolerance-level:
    float noise can flip warp-path ties, which moves individual moments
    but not scores)."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw
    from repro.core.database import pack_series
    from repro.core.similarity import prefix_similarity_bank

    rng = np.random.default_rng(5)
    series = []
    for i in range(5):
        l = int(rng.integers(16, 40))
        t = np.linspace(0, 1, l, dtype=np.float32)
        series.append(np.clip(
            0.5 + 0.3 * np.sin(2 * np.pi * (1.5 + i) * t)
            + 0.05 * rng.normal(size=l), 0, 1).astype(np.float32))
    bank = pack_series(series)
    k, m = bank.series.shape
    J, C, nticks = 2, 8, 4
    qlen = nticks * C
    qs = np.stack([np.clip(
        0.5 + 0.3 * np.sin(2 * np.pi * (2 + j) * np.linspace(0, 1, qlen))
        + 0.05 * rng.normal(size=qlen), 0, 1).astype(np.float32)
        for j in range(J)])
    rows = jnp.full((J, m, k), _dtw._INF)
    moms = jnp.zeros((3, J, m, k))
    ns = jnp.zeros((J,), jnp.int32)
    sx = jnp.zeros((J,))
    sxx = jnp.zeros((J,))
    qlens = jnp.full((J,), qlen, jnp.int32)
    rows_h = jnp.full((J, k, m), _dtw._INF)
    ns_h = jnp.zeros((J,), jnp.int32)
    collected = []
    for t0 in range(nticks):
        ch = jnp.asarray(qs[:, t0 * C:(t0 + 1) * C])
        nv = jnp.full((J,), C, jnp.int32)
        rows, moms, ns, sx, sxx, scores = \
            _dtw.bank_extend_tick_scored_dispatch(
                rows, moms, ns, sx, sxx, jnp.asarray(bank.series.T),
                jnp.asarray(bank.lengths), ch, nv, qlens,
                use_kernel=True, interpret=True)
        rows_h, ns_h, coll = _dtw._bank_extend_many(
            rows_h, ns_h, jnp.asarray(bank.series),
            jnp.asarray(bank.lengths), ch, nv, qlens, None, True)
        collected.append(np.asarray(coll))
        stack = np.concatenate(collected)
        dev = np.asarray(scores)
        for j in range(J):
            host = prefix_similarity_bank(qs[j, :(t0 + 1) * C], bank,
                                          stack[:, j])
            np.testing.assert_allclose(dev[j], host, atol=2e-3)


@pytest.mark.parametrize("band,block_k", [(None, 128), (6, 128), (None, 4)])
def test_stream_kernel_cell_by_cell_vs_bank_extend(band, block_k):
    """Pallas streaming bank-extend == core.dtw._bank_extend_many on every
    cell, across multiple ragged chunks (random per-job nvalid), ragged
    reference lengths, banded and unbanded, including a block_k that
    forces reference-tile padding."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw
    from repro.core.database import pack_series
    from repro.kernels.dtw import stream_bank_extend_kernel

    rng = np.random.default_rng(0 if band is None else band)
    series = [rng.random(int(rng.integers(12, 30))).astype(np.float32)
              for _ in range(7)]
    bank = pack_series(series)
    k, m = bank.series.shape
    J, C = 3, 8
    rows_p = jnp.full((J, k, m), _dtw._INF)
    ns_p = jnp.zeros((J,), jnp.int32)
    rows_h, ns_h = rows_p, ns_p
    qlens = jnp.full((J,), 4 * C, jnp.int32)
    for _ in range(4):
        nv = jnp.asarray(rng.integers(0, C + 1, size=J).astype(np.int32))
        ch = jnp.asarray(rng.random((J, C)).astype(np.float32))
        rows_p, ns_p = stream_bank_extend_kernel(
            rows_p, ns_p, bank.series, bank.lengths, ch, nv, qlens,
            band=band, block_k=block_k, interpret=True)
        rows_h, ns_h, _ = _dtw._bank_extend_many(
            rows_h, ns_h, jnp.asarray(bank.series),
            jnp.asarray(bank.lengths), ch, nv, qlens, band, False)
    r1, r2 = np.asarray(rows_p), np.asarray(rows_h)
    finite = r2 < 1e37
    assert (finite == (r1 < 1e37)).all()
    np.testing.assert_allclose(r1[finite], r2[finite], rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ns_p), np.asarray(ns_h))


# ---------------------------------------------------------------------------
# Variance-carrying (uncertain-series) DP
# ---------------------------------------------------------------------------

def _var_bank(rng, k=7):
    from repro.core.database import pack_series
    return pack_series([_dyadic_series(rng, int(rng.integers(12, 30)))
                        for _ in range(k)])


@pytest.mark.parametrize("band,block_k", [(None, 128), (6, 128), (None, 4),
                                          (6, 4)])
def test_stream_scored_var_kernel_cell_by_cell(band, block_k):
    """Variance-mode Pallas streaming kernel == the jnp variance wavefront
    on every cell — DP rows, all SIX moment slabs, scores, probs and the
    (sv, svx, svxx) folds — across ragged banks, bands, ragged chunks and
    tile padding; exact on dyadic grids (variances dyadic too, so every
    variance-channel product is exactly representable)."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw

    rng = np.random.default_rng(23 if band is None else band + block_k)
    bank = _var_bank(rng)
    k, m = bank.series.shape
    J, C = 3, 8
    qlens = jnp.full((J,), 4 * C, jnp.int32)
    bank_t = jnp.asarray(bank.series.T)
    lengths = jnp.asarray(bank.lengths)
    rows_w = jnp.full((J, m, k), _dtw._INF)
    moms_w = jnp.zeros((6, J, m, k))
    ns_w = jnp.zeros((J,), jnp.int32)
    sx_w = jnp.zeros((J,))
    sxx_w = jnp.zeros((J,))
    vst_w = jnp.zeros((J, 3))
    state_p = (rows_w, moms_w, ns_w, sx_w, sxx_w, vst_w)
    state_w = state_p
    for _ in range(4):
        nv = jnp.asarray(rng.integers(0, C + 1, size=J).astype(np.int32))
        ch = jnp.asarray((rng.integers(0, 9, (J, C)) / 8.0)
                         .astype(np.float32))
        vch = jnp.asarray((rng.integers(0, 5, (J, C)) / 64.0)
                          .astype(np.float32))
        *state_w, sc_w, vw, pr_w = _dtw.bank_extend_tick_scored_var(
            state_w[0], state_w[1], state_w[2], state_w[3], state_w[4],
            state_w[5], bank_t, lengths, ch, vch, nv, qlens, band=band,
            threshold=0.85)
        state_w = state_w[:5] + [vw]
        *state_p, sc_p, vp, pr_p = \
            _dtw.bank_extend_tick_scored_var_dispatch(
                state_p[0], state_p[1], state_p[2], state_p[3], state_p[4],
                state_p[5], bank_t, lengths, ch, vch, nv, qlens, band=band,
                threshold=0.85, use_kernel=True, interpret=True,
                block_k=block_k)
        state_p = state_p[:5] + [vp]
        rp, rw = np.asarray(state_p[0]), np.asarray(state_w[0])
        finite = rw < 1e37
        assert (finite == (rp < 1e37)).all()
        np.testing.assert_array_equal(rp[finite], rw[finite])
        mp, mw = np.asarray(state_p[1]), np.asarray(state_w[1])
        fin6 = np.broadcast_to(finite[None], mp.shape)
        np.testing.assert_array_equal(mp[fin6], mw[fin6])
        np.testing.assert_array_equal(np.asarray(sc_p), np.asarray(sc_w))
        np.testing.assert_array_equal(np.asarray(pr_p), np.asarray(pr_w))
        np.testing.assert_array_equal(np.asarray(vp), np.asarray(vw))
    np.testing.assert_array_equal(np.asarray(state_p[2]),
                                  np.asarray(state_w[2]))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_stream_var_zero_variance_reduces_bitwise(use_kernel):
    """With all-zero per-sample variances the variance tick's rows, base
    moment slabs and scores are BIT-IDENTICAL to the exact scored tick's,
    and every probability is exactly 0.0 or 1.0 with
    ``prob == 1 <=> score >= threshold``."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw

    rng = np.random.default_rng(31)
    bank = _var_bank(rng)
    k, m = bank.series.shape
    J, C = 3, 8
    qlens = jnp.full((J,), 4 * C, jnp.int32)
    bank_t = jnp.asarray(bank.series.T)
    lengths = jnp.asarray(bank.lengths)
    rows_e = jnp.full((J, m, k), _dtw._INF)
    moms_e = jnp.zeros((3, J, m, k))
    ns_e = jnp.zeros((J,), jnp.int32)
    sx_e = jnp.zeros((J,))
    sxx_e = jnp.zeros((J,))
    rows_v, moms_v = rows_e, jnp.zeros((6, J, m, k))
    ns_v, sx_v, sxx_v = ns_e, sx_e, sxx_e
    vst = jnp.zeros((J, 3))
    thr = 0.85
    for _ in range(4):
        nv = jnp.asarray(rng.integers(0, C + 1, size=J).astype(np.int32))
        ch = jnp.asarray((rng.integers(0, 9, (J, C)) / 8.0)
                         .astype(np.float32))
        vch = jnp.zeros((J, C))
        rows_e, moms_e, ns_e, sx_e, sxx_e, sc_e = \
            _dtw.bank_extend_tick_scored(rows_e, moms_e, ns_e, sx_e,
                                         sxx_e, bank_t, lengths, ch, nv,
                                         qlens, band=6)
        (rows_v, moms_v, ns_v, sx_v, sxx_v, sc_v, vst,
         pr) = _dtw.bank_extend_tick_scored_var_dispatch(
            rows_v, moms_v, ns_v, sx_v, sxx_v, vst, bank_t, lengths, ch,
            vch, nv, qlens, band=6, threshold=thr, use_kernel=use_kernel,
            interpret=True if use_kernel else None)
        np.testing.assert_array_equal(np.asarray(rows_v),
                                      np.asarray(rows_e))
        np.testing.assert_array_equal(np.asarray(moms_v)[:3],
                                      np.asarray(moms_e))
        np.testing.assert_array_equal(np.asarray(sc_v), np.asarray(sc_e))
        assert np.asarray(vst).max() == 0.0
        pr, sc = np.asarray(pr), np.asarray(sc_e)
        assert set(np.unique(pr)) <= {0.0, 1.0}
        np.testing.assert_array_equal(pr == 1.0, sc >= thr)


def test_stream_var_chunking_invariance():
    """Any chunking of the variance-mode stream reproduces the one-shot
    solve BITWISE on dyadic grids (samples and variances both dyadic):
    rows, all six moment slabs, variance folds, scores and probs."""
    from _hypothesis_compat import given, settings, st
    import jax.numpy as jnp
    from repro.core import dtw as _dtw

    rng0 = np.random.default_rng(47)
    bank = _var_bank(rng0, k=5)
    k, m = bank.series.shape
    N = 24
    bank_t = jnp.asarray(bank.series.T)
    lengths = jnp.asarray(bank.lengths)
    q = _dyadic_series(rng0, N)
    v = (rng0.integers(0, 5, N) / 64.0).astype(np.float32)

    def run(chunk_sizes):
        rows = jnp.full((1, m, k), _dtw._INF)
        moms = jnp.zeros((6, 1, m, k))
        ns = jnp.zeros((1,), jnp.int32)
        sx = jnp.zeros((1,))
        sxx = jnp.zeros((1,))
        vst = jnp.zeros((1, 3))
        lo = 0
        out = None
        for c in chunk_sizes:
            ch, vch = q[lo:lo + c], v[lo:lo + c]
            lo += c
            (rows, moms, ns, sx, sxx, sc, vst,
             pr) = _dtw.bank_extend_tick_scored_var(
                rows, moms, ns, sx, sxx, vst, bank_t, lengths,
                jnp.asarray(ch[None]), jnp.asarray(vch[None]),
                jnp.asarray([len(ch)], np.int32),
                jnp.asarray([N], np.int32), band=4, threshold=0.85)
            out = (rows, moms, vst, sc, pr)
        return [np.asarray(a) for a in out]

    ref = run([N])

    @settings(max_examples=12, deadline=None)
    @given(st.integers(min_value=1, max_value=N - 1))
    def prop(c):
        sizes = [c] * (N // c)
        if N % c:
            sizes.append(N % c)
        got = run(sizes)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a, b)

    prop()


@pytest.mark.parametrize("band,block_k", [(None, 128), (6, 128), (None, 4),
                                          (6, 4)])
def test_offline_var_kernel_cell_by_cell(band, block_k):
    """Offline variance Pallas kernel == the variance wavefront tile
    scorer bitwise (scores, probs, distances) on dyadic data across
    ragged banks/queries, bands and tile padding; zero variance further
    reduces bitwise to the exact offline kernel."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw
    from repro.kernels.dtw import (score_bank_offline_kernel,
                                   score_bank_offline_var_kernel)

    rng = np.random.default_rng(3 if band is None else 10 * band + block_k)
    bank = _var_bank(rng)
    k, m = bank.series.shape
    J, n = 3, 20
    xlens = np.asarray([20, 13, 17], np.int32)
    xs = np.zeros((J, n), np.float32)
    xv = np.zeros((J, n), np.float32)
    for i, L in enumerate(xlens):
        xs[i, :L] = _dyadic_series(rng, L)
        xv[i, :L] = (rng.integers(0, 5, L) / 64.0).astype(np.float32)
    sx = np.zeros(J, np.float32)
    sxx = np.zeros(J, np.float32)
    vst = np.zeros((J, 3), np.float32)
    for i, L in enumerate(xlens):
        sx[i], sxx[i] = _dtw.query_moments(xs[i, :L])
        vst[i] = _dtw.query_var_moments(xs[i, :L], xv[i, :L])
    ks, kp, kd = score_bank_offline_var_kernel(
        xs, xv, xlens, bank.series, bank.lengths, sx, sxx, vst,
        band=band, threshold=0.85, block_k=block_k, interpret=True)
    ws, wp, wd = _dtw._score_tile_var_many(
        jnp.asarray(xs), jnp.asarray(xv), jnp.asarray(xlens),
        jnp.asarray(bank.series), jnp.asarray(bank.lengths),
        jnp.asarray(sx), jnp.asarray(sxx), jnp.asarray(vst), band, 0.85)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(ws))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(wp))
    np.testing.assert_array_equal(np.asarray(kd), np.asarray(wd))

    zs, zp, zd = score_bank_offline_var_kernel(
        xs, np.zeros_like(xv), xlens, bank.series, bank.lengths, sx, sxx,
        np.zeros_like(vst), band=band, threshold=0.85, block_k=block_k,
        interpret=True)
    es, ed = score_bank_offline_kernel(xs, xlens, bank.series,
                                       bank.lengths, sx, sxx, band=band,
                                       block_k=block_k, interpret=True)
    np.testing.assert_array_equal(np.asarray(zs), np.asarray(es))
    np.testing.assert_array_equal(np.asarray(zd), np.asarray(ed))
    zp = np.asarray(zp)
    assert set(np.unique(zp)) <= {0.0, 1.0}
    np.testing.assert_array_equal(zp == 1.0, np.asarray(zs) >= 0.85)


def test_score_bank_many_var_jnp_vs_kernel():
    """`dtw_score_bank_many(xvars=...)`'s jnp tile path and its Pallas
    kernel path agree bitwise on dyadic data, and the returned probs are
    finite probabilities."""
    from repro.core import dtw as _dtw

    rng = np.random.default_rng(91)
    bank = _var_bank(rng, k=9)
    J, n = 2, 24
    xs = np.stack([_dyadic_series(rng, n) for _ in range(J)])
    xv = (rng.integers(0, 5, (J, n)) / 64.0).astype(np.float32)
    a = _dtw.dtw_score_bank_many(xs, bank.series, bank.lengths,
                                 band=6, xvars=xv, threshold=0.85,
                                 use_kernel=False)
    b = _dtw.dtw_score_bank_many(xs, bank.series, bank.lengths,
                                 band=6, xvars=xv, threshold=0.85,
                                 use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    p = np.asarray(a[1])
    assert np.isfinite(p).all() and (p >= 0).all() and (p <= 1).all()

# ---------------------------------------------------------------------------
# Approximate-tail (4-channel) variance DP
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("band,block_k", [(None, 128), (6, 128), (None, 4),
                                          (6, 4)])
def test_stream_scored_var_approx_kernel_cell_by_cell(band, block_k):
    """Approx-mode (FOUR moment channels) Pallas streaming kernel == the
    jnp variance wavefront on every cell — DP rows, all four slabs, the
    (sv, svx, svxx) folds, scores and approx probs — across ragged
    banks, bands, ragged chunks and tile padding; exact on dyadic
    grids."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw

    rng = np.random.default_rng(53 if band is None else band + block_k)
    bank = _var_bank(rng)
    k, m = bank.series.shape
    J, C = 3, 8
    qlens = jnp.full((J,), 4 * C, jnp.int32)
    bank_t = jnp.asarray(bank.series.T)
    lengths = jnp.asarray(bank.lengths)
    rows_w = jnp.full((J, m, k), _dtw._INF)
    moms_w = jnp.zeros((4, J, m, k))
    ns_w = jnp.zeros((J,), jnp.int32)
    sx_w = jnp.zeros((J,))
    sxx_w = jnp.zeros((J,))
    vst_w = jnp.zeros((J, 3))
    state_p = (rows_w, moms_w, ns_w, sx_w, sxx_w, vst_w)
    state_w = state_p
    for _ in range(4):
        nv = jnp.asarray(rng.integers(0, C + 1, size=J).astype(np.int32))
        ch = jnp.asarray((rng.integers(0, 9, (J, C)) / 8.0)
                         .astype(np.float32))
        vch = jnp.asarray((rng.integers(0, 5, (J, C)) / 64.0)
                          .astype(np.float32))
        *state_w, sc_w, vw, pr_w = _dtw.bank_extend_tick_scored_var_approx(
            state_w[0], state_w[1], state_w[2], state_w[3], state_w[4],
            state_w[5], bank_t, lengths, ch, vch, nv, qlens, band=band,
            threshold=0.85)
        state_w = state_w[:5] + [vw]
        *state_p, sc_p, vp, pr_p = \
            _dtw.bank_extend_tick_scored_var_approx_dispatch(
                state_p[0], state_p[1], state_p[2], state_p[3], state_p[4],
                state_p[5], bank_t, lengths, ch, vch, nv, qlens, band=band,
                threshold=0.85, use_kernel=True, interpret=True,
                block_k=block_k)
        state_p = state_p[:5] + [vp]
        rp, rw = np.asarray(state_p[0]), np.asarray(state_w[0])
        finite = rw < 1e37
        assert (finite == (rp < 1e37)).all()
        np.testing.assert_array_equal(rp[finite], rw[finite])
        mp, mw = np.asarray(state_p[1]), np.asarray(state_w[1])
        fin4 = np.broadcast_to(finite[None], mp.shape)
        np.testing.assert_array_equal(mp[fin4], mw[fin4])
        np.testing.assert_array_equal(np.asarray(sc_p), np.asarray(sc_w))
        np.testing.assert_array_equal(np.asarray(pr_p), np.asarray(pr_w))
        np.testing.assert_array_equal(np.asarray(vp), np.asarray(vw))
    np.testing.assert_array_equal(np.asarray(state_p[2]),
                                  np.asarray(state_w[2]))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_stream_var_approx_zero_variance_reduces_bitwise(use_kernel):
    """With all-zero per-sample variances the APPROX tick's rows, base
    moment slabs and scores are BIT-IDENTICAL to the exact scored
    tick's, every probability is exactly 0.0 or 1.0 with
    ``prob == 1 <=> score >= threshold`` — and the probs are bitwise
    equal to the exact six-channel variance tick's (the approx tail
    reduces to the same point rule, not just the same set)."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw

    rng = np.random.default_rng(59)
    bank = _var_bank(rng)
    k, m = bank.series.shape
    J, C = 3, 8
    qlens = jnp.full((J,), 4 * C, jnp.int32)
    bank_t = jnp.asarray(bank.series.T)
    lengths = jnp.asarray(bank.lengths)
    rows_e = jnp.full((J, m, k), _dtw._INF)
    moms_e = jnp.zeros((3, J, m, k))
    ns_e = jnp.zeros((J,), jnp.int32)
    sx_e = jnp.zeros((J,))
    sxx_e = jnp.zeros((J,))
    rows_a, moms_a = rows_e, jnp.zeros((4, J, m, k))
    ns_a, sx_a, sxx_a = ns_e, sx_e, sxx_e
    vst_a = jnp.zeros((J, 3))
    rows_v, moms_v = rows_e, jnp.zeros((6, J, m, k))
    ns_v, sx_v, sxx_v, vst_v = ns_e, sx_e, sxx_e, vst_a
    thr = 0.85
    for _ in range(4):
        nv = jnp.asarray(rng.integers(0, C + 1, size=J).astype(np.int32))
        ch = jnp.asarray((rng.integers(0, 9, (J, C)) / 8.0)
                         .astype(np.float32))
        vch = jnp.zeros((J, C))
        rows_e, moms_e, ns_e, sx_e, sxx_e, sc_e = \
            _dtw.bank_extend_tick_scored(rows_e, moms_e, ns_e, sx_e,
                                         sxx_e, bank_t, lengths, ch, nv,
                                         qlens, band=6)
        (rows_a, moms_a, ns_a, sx_a, sxx_a, sc_a, vst_a,
         pr_a) = _dtw.bank_extend_tick_scored_var_approx_dispatch(
            rows_a, moms_a, ns_a, sx_a, sxx_a, vst_a, bank_t, lengths, ch,
            vch, nv, qlens, band=6, threshold=thr, use_kernel=use_kernel,
            interpret=True if use_kernel else None)
        (rows_v, moms_v, ns_v, sx_v, sxx_v, sc_v, vst_v,
         pr_v) = _dtw.bank_extend_tick_scored_var_dispatch(
            rows_v, moms_v, ns_v, sx_v, sxx_v, vst_v, bank_t, lengths, ch,
            vch, nv, qlens, band=6, threshold=thr, use_kernel=use_kernel,
            interpret=True if use_kernel else None)
        np.testing.assert_array_equal(np.asarray(rows_a),
                                      np.asarray(rows_e))
        np.testing.assert_array_equal(np.asarray(moms_a)[:3],
                                      np.asarray(moms_e))
        np.testing.assert_array_equal(np.asarray(sc_a), np.asarray(sc_e))
        assert np.asarray(vst_a).max() == 0.0
        pr, sc = np.asarray(pr_a), np.asarray(sc_e)
        assert set(np.unique(pr)) <= {0.0, 1.0}
        np.testing.assert_array_equal(pr == 1.0, sc >= thr)
        np.testing.assert_array_equal(pr, np.asarray(pr_v))


def test_stream_var_approx_chunking_invariance():
    """Any chunking of the approx-mode stream reproduces the one-shot
    solve BITWISE on dyadic grids: rows, all four moment slabs,
    variance folds, scores and approx probs."""
    from _hypothesis_compat import given, settings, st
    import jax.numpy as jnp
    from repro.core import dtw as _dtw

    rng0 = np.random.default_rng(61)
    bank = _var_bank(rng0, k=5)
    k, m = bank.series.shape
    N = 24
    bank_t = jnp.asarray(bank.series.T)
    lengths = jnp.asarray(bank.lengths)
    q = _dyadic_series(rng0, N)
    v = (rng0.integers(0, 5, N) / 64.0).astype(np.float32)

    def run(chunk_sizes):
        rows = jnp.full((1, m, k), _dtw._INF)
        moms = jnp.zeros((4, 1, m, k))
        ns = jnp.zeros((1,), jnp.int32)
        sx = jnp.zeros((1,))
        sxx = jnp.zeros((1,))
        vst = jnp.zeros((1, 3))
        lo = 0
        out = None
        for c in chunk_sizes:
            ch, vch = q[lo:lo + c], v[lo:lo + c]
            lo += c
            (rows, moms, ns, sx, sxx, sc, vst,
             pr) = _dtw.bank_extend_tick_scored_var_approx(
                rows, moms, ns, sx, sxx, vst, bank_t, lengths,
                jnp.asarray(ch[None]), jnp.asarray(vch[None]),
                jnp.asarray([len(ch)], np.int32),
                jnp.asarray([N], np.int32), band=4, threshold=0.85)
            out = (rows, moms, vst, sc, pr)
        return [np.asarray(a) for a in out]

    ref = run([N])

    @settings(max_examples=12, deadline=None)
    @given(st.integers(min_value=1, max_value=N - 1))
    def prop(c):
        sizes = [c] * (N // c)
        if N % c:
            sizes.append(N % c)
        got = run(sizes)
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(a, b)

    prop()


@pytest.mark.parametrize("band,block_k", [(None, 128), (6, 128), (None, 4),
                                          (6, 4)])
def test_offline_var_approx_kernel_cell_by_cell(band, block_k):
    """Offline approx-tail Pallas kernel == the variance wavefront tile
    scorer with ``approx=True`` bitwise (scores, probs, distances) on
    dyadic data across ragged banks/queries, bands and tile padding;
    zero variance further reduces bitwise to the exact offline
    kernel."""
    import jax.numpy as jnp
    from repro.core import dtw as _dtw
    from repro.kernels.dtw import (score_bank_offline_kernel,
                                   score_bank_offline_var_approx_kernel)

    rng = np.random.default_rng(7 if band is None else 20 * band + block_k)
    bank = _var_bank(rng)
    k, m = bank.series.shape
    J, n = 3, 20
    xlens = np.asarray([20, 13, 17], np.int32)
    xs = np.zeros((J, n), np.float32)
    xv = np.zeros((J, n), np.float32)
    for i, L in enumerate(xlens):
        xs[i, :L] = _dyadic_series(rng, L)
        xv[i, :L] = (rng.integers(0, 5, L) / 64.0).astype(np.float32)
    sx = np.zeros(J, np.float32)
    sxx = np.zeros(J, np.float32)
    vst = np.zeros((J, 3), np.float32)
    for i, L in enumerate(xlens):
        sx[i], sxx[i] = _dtw.query_moments(xs[i, :L])
        vst[i] = _dtw.query_var_moments(xs[i, :L], xv[i, :L])
    ks, kp, kd = score_bank_offline_var_approx_kernel(
        xs, xv, xlens, bank.series, bank.lengths, sx, sxx, vst,
        band=band, threshold=0.85, block_k=block_k, interpret=True)
    ws, wp, wd = _dtw._score_tile_var_many(
        jnp.asarray(xs), jnp.asarray(xv), jnp.asarray(xlens),
        jnp.asarray(bank.series), jnp.asarray(bank.lengths),
        jnp.asarray(sx), jnp.asarray(sxx), jnp.asarray(vst), band, 0.85,
        approx=True)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(ws))
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(wp))
    np.testing.assert_array_equal(np.asarray(kd), np.asarray(wd))

    zs, zp, zd = score_bank_offline_var_approx_kernel(
        xs, np.zeros_like(xv), xlens, bank.series, bank.lengths, sx, sxx,
        np.zeros_like(vst), band=band, threshold=0.85, block_k=block_k,
        interpret=True)
    es, ed = score_bank_offline_kernel(xs, xlens, bank.series,
                                       bank.lengths, sx, sxx, band=band,
                                       block_k=block_k, interpret=True)
    np.testing.assert_array_equal(np.asarray(zs), np.asarray(es))
    np.testing.assert_array_equal(np.asarray(zd), np.asarray(ed))
    zp = np.asarray(zp)
    assert set(np.unique(zp)) <= {0.0, 1.0}
    np.testing.assert_array_equal(zp == 1.0, np.asarray(zs) >= 0.85)


def test_score_bank_many_prob_mode_approx_jnp_vs_kernel():
    """`dtw_score_bank_many(prob_mode="approx")`'s jnp tile path and its
    Pallas kernel path agree bitwise on dyadic data, the returned probs
    are finite probabilities, and scores/distances are bitwise
    independent of prob_mode (the approx tail only changes the
    probability channel)."""
    from repro.core import dtw as _dtw

    rng = np.random.default_rng(97)
    bank = _var_bank(rng, k=9)
    J, n = 2, 24
    xs = np.stack([_dyadic_series(rng, n) for _ in range(J)])
    xv = (rng.integers(0, 5, (J, n)) / 64.0).astype(np.float32)
    a = _dtw.dtw_score_bank_many(xs, bank.series, bank.lengths,
                                 band=6, xvars=xv, threshold=0.85,
                                 prob_mode="approx", use_kernel=False)
    b = _dtw.dtw_score_bank_many(xs, bank.series, bank.lengths,
                                 band=6, xvars=xv, threshold=0.85,
                                 prob_mode="approx", use_kernel=True,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    p = np.asarray(a[1])
    assert np.isfinite(p).all() and (p >= 0).all() and (p <= 1).all()
    e = _dtw.dtw_score_bank_many(xs, bank.series, bank.lengths,
                                 band=6, xvars=xv, threshold=0.85,
                                 use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(e[0]))

    with pytest.raises(ValueError):
        _dtw.dtw_score_bank_many(xs, bank.series, bank.lengths, band=6,
                                 xvars=xv, prob_mode="bogus")
