"""Overload-safe serving: admission, degradation ladder, breaker.

The robustness contract of PR "overload-safe serving":

* the :class:`OverloadController` ladder escalates/de-escalates on the
  EWMA'd window-p99 tick latency with patience/cooldown hysteresis, and
  its whole trajectory is recorded in ``rung_history``;
* admission thresholds are QoS-ordered — gold is NEVER shed at a
  pressure that admits bronze — and a shed submit leaves no state;
* every ladder rung may delay decisions but never change them: under a
  seeded 10x submission spike with slow-dispatch chaos the service walks
  the ladder, sheds bronze spike jobs, and still emits decisions
  bitwise identical to the unloaded golden run (the golden overload
  test);
* the :class:`CircuitBreaker` generalises the one-shot kernel fallback:
  a persistent fault burst trips it OPEN (fallback served directly),
  and after the burst ends a seeded half-open probe re-promotes the
  kernel path — ``degraded`` clears, decisions bitwise unchanged;
* ``call_with_retry(max_elapsed=...)`` abandons remaining retries at a
  wall-clock deadline without perturbing the seeded jitter stream;
* a degraded journal (``TraceLog.journal_degraded``) never loses
  accepted commands and ``checkpoint()`` refuses to advance the
  watermark past them;
* the whole control plane snapshots/restores mid-ladder bit-identically.

The fast CI chaos job runs this module over a fixed seed matrix via the
``CHAOS_SEEDS`` env var (comma-separated ints).
"""
import os

import numpy as np
import pytest

from repro.core.database import pack_series
from repro.runtime.chaos import FaultPlan
from repro.runtime.fault import ElasticController
from repro.runtime.retry import CircuitBreaker, RetryPolicy, call_with_retry
from repro.serve.ingest import BackpressureError, TraceLog
from repro.serve.overload import (RUNGS, AdmissionController,
                                  AdmissionPolicy, AdmissionShedError,
                                  OverloadConfig, OverloadController)
from repro.serve.recovery import (RecoverableTuningService,
                                  restore_service, snapshot_service)
from repro.serve.tuning import TuningService

SEEDS = [int(s) for s in os.environ.get("CHAOS_SEEDS", "5,17").split(",")]


def _bank(k=4, seed=2):
    rng = np.random.default_rng(seed)
    series = [np.abs(np.cumsum(rng.normal(size=100)))
              .astype(np.float32) for _ in range(k)]
    return pack_series(series, labels=[f"w{i}" for i in range(k)])


def _streams(n=3, seed=3, length=48):
    r = np.random.default_rng(seed)
    return {f"j{i}": np.abs(np.cumsum(r.normal(size=length)))
            .astype(np.float32) for i in range(n)}


def _keyd(decisions):
    return sorted((j, None if d is None else
                   (d.matched, float(d.corr).hex(), d.final,
                    tuple((k, float(v).hex())
                          for k, v in sorted(d.scores.items()))))
                  for j, d in decisions.items())


def _policy(**kw):
    kw.setdefault("base_delay", 0.0)
    kw.setdefault("sleep", lambda s: None)
    return RetryPolicy(**kw)


# ---------------------------------------------------------------------------
# the ladder controller
# ---------------------------------------------------------------------------

class TestOverloadController:
    def test_escalates_after_patience_and_records_history(self):
        c = OverloadController(OverloadConfig(target_p99=0.1, patience=2,
                                              cooldown=3, window=8))
        assert c.observe(10.0) == 0          # hot once: patience not met
        assert c.observe(10.0) == 1          # hot twice: escalate
        assert c.observe(10.0) == 1
        assert c.observe(10.0) == 2
        assert c.rung_history == [(2, 0, 1), (4, 1, 2)]

    def test_deescalates_after_cooldown(self):
        c = OverloadController(OverloadConfig(target_p99=0.1, patience=1,
                                              cooldown=2, window=2))
        c.observe(10.0)
        assert c.rung == 1
        # window=2 pushes the spike out after two calm ticks; EWMA decays
        for _ in range(40):
            c.observe(0.0)
        assert c.rung == 0
        assert c.rung_history[-1][2] == 0

    def test_max_rung_caps_escalation(self):
        c = OverloadController(OverloadConfig(target_p99=0.01, patience=1,
                                              max_rung=2))
        for _ in range(10):
            c.observe(5.0)
        assert c.rung == 2

    def test_derived_knobs_by_rung(self):
        c = OverloadController(OverloadConfig(cohort_scale=4.0))
        caps = {}
        for r in range(len(RUNGS)):
            c.rung = r
            caps[r] = (c.tick_mode_cap, c.prefilter_divisor, c.cohort_scale)
        assert caps[0] == ("prob", 1, 1.0)
        assert caps[1] == ("approx_prob", 1, 1.0)
        assert caps[2] == ("scored", 1, 1.0)
        assert caps[3] == ("distance", 1, 1.0)
        assert caps[4] == ("distance", 2, 1.0)
        assert caps[5] == ("distance", 2, 4.0)
        assert caps[6] == ("distance", 2, 4.0)

    def test_state_roundtrip_resumes_identically(self):
        import json
        cfg = OverloadConfig(target_p99=0.1, patience=2, cooldown=2,
                             window=4)
        a = OverloadController(cfg)
        lat = [10.0, 10.0, 0.0, 10.0, 10.0]
        for v in lat:
            a.observe(v)
        st = json.loads(json.dumps(a.state_dict()))   # JSON-able
        b = OverloadController(cfg)
        b.load_state(st)
        tail = [10.0, 0.0, 0.0, 0.0, 10.0, 10.0]
        assert [a.observe(v) for v in tail] == [b.observe(v) for v in tail]
        assert a.rung_history == b.rung_history

    def test_config_validation(self):
        with pytest.raises(ValueError):
            OverloadConfig(target_p99=0.0)
        with pytest.raises(ValueError):
            OverloadConfig(patience=0)
        with pytest.raises(ValueError):
            OverloadConfig(ewma_alpha=0.0)
        with pytest.raises(ValueError):
            OverloadConfig(max_rung=len(RUNGS))
        with pytest.raises(ValueError):
            OverloadConfig(cohort_scale=0.5)


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------

class TestAdmission:
    def test_threshold_ordering_enforced(self):
        with pytest.raises(ValueError):
            AdmissionPolicy(bronze=0.9, silver=0.5)
        with pytest.raises(ValueError):
            AdmissionPolicy(silver=0.99, gold=0.98)
        with pytest.raises(ValueError):
            AdmissionPolicy(bronze=0.0)

    def test_unknown_qos_is_error_not_shed(self):
        a = AdmissionController()
        with pytest.raises(ValueError, match="unknown QoS"):
            a.admit("j", qos="platinum", cost_fill=0.0, queue_fill=0.0,
                    rung_frac=0.0)

    def test_gold_never_shed_before_bronze(self):
        a = AdmissionController(AdmissionPolicy())

        def shed(qos, p):
            try:
                a.admit("j", qos=qos, cost_fill=p, queue_fill=0.0,
                        rung_frac=0.0)
                return False
            except AdmissionShedError:
                return True

        for p in np.linspace(0.0, 1.0, 101):
            assert not (shed("gold", p) and not shed("bronze", p))
            assert not (shed("silver", p) and not shed("bronze", p))

    def test_shed_error_carries_context_and_is_backpressure(self):
        a = AdmissionController(AdmissionPolicy(bronze=0.5))
        with pytest.raises(AdmissionShedError) as ei:
            a.admit("jb", qos="bronze", cost_fill=0.2, queue_fill=0.9,
                    rung_frac=0.0)
        e = ei.value
        assert isinstance(e, BackpressureError)
        assert (e.job_id, e.qos) == ("jb", "bronze")
        assert e.pressure == pytest.approx(0.9)
        assert e.threshold == pytest.approx(0.5)

    def test_pressure_is_worst_signal_clipped(self):
        a = AdmissionController()
        assert a.pressure(cost_fill=0.2, queue_fill=0.7,
                          rung_frac=0.4) == pytest.approx(0.7)
        assert a.pressure(cost_fill=3.0, queue_fill=0.0,
                          rung_frac=0.0) == 1.0

    def test_shed_submit_leaves_no_state(self):
        svc = TuningService(_bank(), overload=OverloadConfig(),
                            admission=AdmissionPolicy(bronze=0.1,
                                                      silver=0.1,
                                                      gold=0.1,
                                                      cost_scale=0.01))
        with pytest.raises(AdmissionShedError):
            svc.submit("big", 400, qos="bronze")
        assert svc.n_active == 0
        assert "big" not in svc._jobs
        assert svc.shed_count == 1 and svc.shed_by_class == {"bronze": 1}
        # relaxing the gate admits the same id cleanly
        svc._admission_suppressed = True
        svc.submit("big", 400, qos="bronze")
        assert svc.n_active == 1


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

class TestCircuitBreaker:
    def test_state_machine_walk(self):
        br = CircuitBreaker(fail_threshold=2, cooldown=3,
                            probe_interval=1, seed=0)
        assert br.before_dispatch() == "primary"
        br.record_failure()
        assert br.state == br.CLOSED
        br.record_failure()                        # trips
        assert br.state == br.OPEN and br.opened_count == 1
        routes = [br.before_dispatch() for _ in range(3)]
        assert routes == ["fallback"] * 3          # cooldown served
        assert br.state == br.HALF_OPEN
        assert br.before_dispatch() == "probe"     # probe_interval=1
        br.record_success()                        # probe re-promotes
        assert br.state == br.CLOSED and br.reclosed_count == 1
        assert not br.engaged

    def test_failed_probe_reopens(self):
        br = CircuitBreaker(fail_threshold=1, cooldown=1,
                            probe_interval=1, seed=0)
        br.record_failure()
        assert br.state == br.OPEN
        br.before_dispatch()                       # -> half-open
        assert br.before_dispatch() == "probe"
        br.record_failure()
        assert br.state == br.OPEN and br.opened_count == 2

    @pytest.mark.parametrize("seed", SEEDS)
    def test_state_roundtrip_preserves_probe_schedule(self, seed):
        import json

        def routes(br, n):
            out = []
            for _ in range(n):
                r = br.before_dispatch()
                out.append(r)
                if r == "probe":
                    br.record_failure()            # keep it cycling
            return out

        a = CircuitBreaker(fail_threshold=1, cooldown=2,
                           probe_interval=5, seed=seed)
        a.record_failure()
        routes(a, 7)
        st = json.loads(json.dumps(a.state_dict()))
        b = CircuitBreaker(fail_threshold=1, cooldown=2,
                           probe_interval=5, seed=seed + 999)
        b.load_state(st)
        assert routes(a, 30) == routes(b, 30)

    def test_validation(self):
        with pytest.raises(ValueError):
            CircuitBreaker(fail_threshold=0)
        with pytest.raises(ValueError):
            CircuitBreaker(cooldown=0)


# ---------------------------------------------------------------------------
# retry deadline (satellite a)
# ---------------------------------------------------------------------------

class TestRetryDeadline:
    def test_deadline_abandons_retries_to_fallback(self):
        t = [0.0]
        calls = []

        def clock():
            return t[0]

        def sleeper(d):
            t[0] += d

        def fn():
            calls.append("primary")
            raise OSError("transient")

        pol = _policy(max_retries=10, base_delay=1.0, jitter=0.0,
                      sleep=sleeper)
        out, rep = call_with_retry(fn, policy=pol, transient=(OSError,),
                                   fallback=lambda: "fb",
                                   max_elapsed=2.5, clock=clock)
        assert out == "fb" and rep["degraded"]
        # attempts at t=0 (sleep 1), t=1 (sleep 2 -> 3 > 2.5: abandon)
        assert calls == ["primary", "primary"]

    def test_jitter_stream_unchanged_when_deadline_not_hit(self):
        def run(max_elapsed):
            slept = []
            pol = _policy(max_retries=3, base_delay=0.01, seed=7,
                          sleep=slept.append)
            fails = [0]

            def fn():
                if fails[0] < 3:
                    fails[0] += 1
                    raise OSError("transient")
                return "ok"

            out, rep = call_with_retry(fn, policy=pol,
                                       transient=(OSError,),
                                       max_elapsed=max_elapsed)
            return out, rep, slept

        a = run(None)
        b = run(1e9)
        assert a == b and a[0] == "ok" and a[1]["retries"] == 3

    def test_no_deadline_report_unchanged_on_exhaustion(self):
        pol = _policy(max_retries=2)

        def fn():
            raise OSError("transient")

        out, rep = call_with_retry(fn, policy=pol, transient=(OSError,),
                                   fallback=lambda: "fb",
                                   max_elapsed=1e9)
        assert out == "fb" and rep["retries"] == 3


# ---------------------------------------------------------------------------
# ladder downgrades: delayed, never different
# ---------------------------------------------------------------------------

def _drive_pair(kw_golden, kw_loaded, hot_ticks):
    """Run the same 3-job schedule through an unloaded and a loaded
    service; the loaded one is pre-heated to its ladder rung by
    ``hot_ticks`` latency-override observations before data flows."""
    streams = _streams()
    outs = {}
    for tag, kw in (("golden", kw_golden), ("loaded", kw_loaded)):
        svc = TuningService(_bank(), **kw)
        if tag == "loaded":
            for _ in range(hot_ticks):
                svc.tick(latency=10.0)
        for j in streams:
            svc.submit(j, 48)
        earlies = []
        for t in range(6):
            for j, s in streams.items():
                svc.push(j, s[t * 8: (t + 1) * 8])
            for j, d in svc.tick().items():
                if d is not None:
                    earlies.append((j, d.matched))
        finals = _keyd(svc.finish_many(list(streams)))
        outs[tag] = (earlies, finals, svc)
    return outs


def test_approx_prob_downgrade_never_changes_a_decision():
    """Rung 1 caps an exact prob-mode service to the approximate
    4-channel probability tick: probabilities keep flowing (precision
    shed, not probabilities), but ticked jobs carry
    ``degraded_level=1`` so any early that still fires used exact
    channels — no early may disagree with the golden verdict — and
    finals are bitwise unchanged (exact offline recompute)."""
    base = dict(min_probability=0.5, margin=0.01, stable_ticks=1,
                min_fraction=0.1)
    out = _drive_pair(
        base,
        dict(base, overload=OverloadConfig(target_p99=0.01, patience=1,
                                           cooldown=1000, window=64,
                                           max_rung=1)),
        hot_ticks=3)
    (ge, gf, gsvc) = out["golden"]
    (le, lf, lsvc) = out["loaded"]
    assert lsvc.worst_rung == 1 and lsvc.overload_ticks > 0
    assert lf == gf                                  # finals bitwise
    assert set(le) <= set(ge)                        # no WRONG earlies
    assert all(j.degraded_level == 0 for j in gsvc._jobs.values())
    assert all(j.degraded_level <= 1 for j in lsvc._jobs.values())


def test_approx_prob_rung_is_noop_for_approx_configured_service():
    """A ``prob_mode="approx"`` service already runs the approximate
    tick as its base mode, so the ``approx_prob`` rung neither degrades
    its jobs nor changes anything: earlies AND finals are bitwise equal
    to its own unloaded run."""
    base = dict(min_probability=0.5, prob_mode="approx", margin=0.01,
                stable_ticks=1, min_fraction=0.1)
    out = _drive_pair(
        base,
        dict(base, overload=OverloadConfig(target_p99=0.01, patience=1,
                                           cooldown=1000, window=64,
                                           max_rung=1)),
        hot_ticks=3)
    (ge, gf, _) = out["golden"]
    (le, lf, lsvc) = out["loaded"]
    assert lsvc.worst_rung == 1
    assert le == ge                                  # earlies unchanged
    assert lf == gf                                  # finals bitwise
    assert all(j.degraded_level == 0 for j in lsvc._jobs.values())


def test_exact_score_downgrade_bitwise_finals_and_no_wrong_earlies():
    """Rung 2 caps a prob-mode service to exact scored ticks: early
    decisions that still fire use the EXACT score channels, finals are
    bitwise unchanged, and ticked jobs carry ``degraded_level=1``."""
    base = dict(min_probability=0.5, margin=0.01, stable_ticks=1,
                min_fraction=0.1)
    out = _drive_pair(
        base,
        dict(base, overload=OverloadConfig(target_p99=0.01, patience=1,
                                           cooldown=1000, window=64,
                                           max_rung=2)),
        hot_ticks=5)
    (ge, gf, gsvc) = out["golden"]
    (le, lf, lsvc) = out["loaded"]
    assert lsvc.worst_rung == 2 and lsvc.overload_ticks > 0
    assert lf == gf                                  # finals bitwise
    assert set(le) <= set(ge)                        # no WRONG earlies
    assert all(j.degraded_level == 0 for j in gsvc._jobs.values())


def test_distance_downgrade_suppresses_earlies_finals_bitwise():
    """Rung 3 caps everything to distance-only ticks: no early decisions
    at all for jobs ticked there (``degraded_level=2``), finals still
    bitwise equal (recomputed offline from the full query)."""
    out = _drive_pair(
        {},
        dict(overload=OverloadConfig(target_p99=0.01, patience=1,
                                     cooldown=1000, window=64,
                                     max_rung=3)),
        hot_ticks=5)
    (ge, gf, _) = out["golden"]
    (le, lf, lsvc) = out["loaded"]
    assert lsvc.worst_rung == 3
    assert le == []                                  # zero early decisions
    assert lf == gf


def test_deep_prune_rung_halves_prefilter_budget():
    svc = TuningService(_bank(k=8), prefilter_top=6,
                        overload=OverloadConfig(target_p99=0.01,
                                                patience=1, cooldown=1000,
                                                max_rung=4))
    for _ in range(8):
        svc.tick(latency=10.0)
    assert svc.rung == 4
    assert svc._overload.prefilter_divisor == 2


def test_slow_cohorts_rung_stretches_tick_rates():
    svc = TuningService(_bank(), overload=OverloadConfig(
        target_p99=0.01, patience=1, cooldown=1000, max_rung=5,
        cohort_scale=8.0))
    svc.submit("a", 48, tick_hz=10.0)
    for _ in range(10):
        svc.tick(now=0.0, latency=10.0)
    assert svc.rung == 5
    svc.tick(now=0.1, latency=10.0)         # due: re-arms 8/10 s ahead
    assert svc._sched.cohorts._next_due[10.0] == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# the golden overload test
# ---------------------------------------------------------------------------

def _golden_run(streams):
    svc = TuningService(_bank(), queue_limit=64)
    for j in streams:
        svc.submit(j, 48)
    earlies = []
    for t in range(6):
        for j, s in streams.items():
            svc.push(j, s[t * 8: (t + 1) * 8])
        for j, d in svc.tick().items():
            if d is not None:
                earlies.append((j, d.matched))
    return earlies, _keyd(svc.finish_many(list(streams)))


@pytest.mark.parametrize("seed", SEEDS)
def test_golden_overload_spike(seed):
    """Seeded 10x submission spike + slow-dispatch chaos: the service
    walks the ladder (non-trivial rung history), sheds bronze spike
    jobs, never blows queue limits — and every decision it emits is a
    decision the unloaded golden run also made (delayed allowed,
    wrong/extra forbidden).  After the burst the ladder de-escalates
    and ``degraded`` clears."""
    streams = _streams()
    g_earlies, g_finals = _golden_run(streams)

    plan = FaultPlan(seed=seed, slow_rate=1.0, slow_extra=10.0,
                     spike_rate=0.5, spike_factor=10.0, spike_len=2)
    svc = TuningService(
        _bank(), queue_limit=64, slots=64,
        overload=OverloadConfig(target_p99=0.2, patience=1, cooldown=2,
                                window=4),
        admission=AdmissionPolicy(), chaos=plan)
    for j in streams:
        svc.submit(j, 48, qos="gold")

    spike_rng = np.random.default_rng((seed, 8))
    n_spike = 0
    earlies = []
    for t in range(6):
        # the spike: a burst beat multiplies offered submissions 10x
        mult = plan.spike_multiplier()
        for i in range(int(mult) - 1):
            try:
                svc.submit(f"spike{t}_{i}", 48, qos="bronze")
                n_spike += 1
            except (AdmissionShedError, RuntimeError):
                pass
        for j, s in streams.items():
            # queue_policy=reject: a push past queue_limit would raise
            # BackpressureError, so completing silently IS the
            # never-exceeds-queue-limits assertion.
            svc.push(j, s[t * 8: (t + 1) * 8])
        for jid in list(svc._jobs):
            if jid.startswith("spike"):
                svc.push(jid, np.abs(spike_rng.normal(size=4))
                         .astype(np.float32))
        for j, d in svc.tick().items():
            if d is not None and not j.startswith("spike"):
                earlies.append((j, d.matched))

    assert svc.worst_rung >= 1 and len(svc.rung_history) >= 1
    assert plan.spiked_beats >= 1 and plan.slowed_dispatches >= 1
    # under-load decisions: delayed allowed, wrong/extra forbidden —
    # numerics are chaos-independent, so any early the loaded run emits
    # must be one the unloaded golden run emitted too.
    assert set(earlies) <= set(g_earlies)
    finals = _keyd(svc.finish_many(list(streams)))
    assert finals == g_finals

    # burst ends: chaos off, ladder walks back down, degraded clears
    svc.chaos = None
    for _ in range(40):
        svc.tick(latency=0.0)
        if svc.rung == 0:
            break
    assert svc.rung == 0
    assert not svc.degraded
    assert svc.rung_history[-1][2] == 0


@pytest.mark.parametrize("seed", SEEDS)
def test_breaker_rides_fault_burst_then_recloses(seed):
    """A persistent dispatch-fault burst trips the breaker OPEN (the
    fallback serves; no more retry ladders); when the burst ends the
    seeded probe re-promotes the kernel path and ``degraded`` clears.
    Decisions stay bitwise equal to the fault-free run throughout."""
    streams = _streams()
    g_earlies, g_finals = _golden_run(streams)

    br = CircuitBreaker(fail_threshold=2, cooldown=2, probe_interval=2,
                        seed=seed)
    svc = TuningService(_bank(), queue_limit=64,
                        retry_policy=_policy(max_retries=1, seed=seed),
                        chaos=FaultPlan(seed=seed, dispatch_fail_rate=1.0),
                        breaker=br)
    for j in streams:
        svc.submit(j, 48)
    for t in range(3):                       # burst: every dispatch fails
        for j, s in streams.items():
            svc.push(j, s[t * 8: (t + 1) * 8])
        svc.tick()
    assert br.state == br.OPEN
    assert svc.degraded and svc.degraded_dispatch_count >= 2

    svc.chaos = None                         # burst over
    for t in range(3, 6):
        for j, s in streams.items():
            svc.push(j, s[t * 8: (t + 1) * 8])
        svc.tick()
    assert br.state == br.CLOSED and br.reclosed_count >= 1
    assert not svc.degraded
    assert _keyd(svc.finish_many(list(streams))) == g_finals


def test_overload_pressure_feeds_rescale_ahead():
    svc = TuningService(_bank(), overload=OverloadConfig(target_p99=0.01,
                                                         patience=1,
                                                         cooldown=1000))
    ec = ElasticController(model_parallel=1)
    calm = ec.decide_ahead(2, range(8),
                           overload_pressure=svc.overload_pressure())
    assert calm.new_data_parallel <= 2       # nothing to grow for
    for _ in range(10):
        svc.tick(latency=10.0)
    hot = ec.decide_ahead(2, range(8),
                          overload_pressure=svc.overload_pressure())
    assert hot.should_rescale and hot.new_data_parallel == 4
    assert "grow-ahead" in hot.reason


class TestDecideAhead:
    def test_grow_capped_at_usable_pow2(self):
        ec = ElasticController(model_parallel=1)
        d = ec.decide_ahead(4, range(6), overload_pressure=1.0)
        assert (d.should_rescale, d.new_data_parallel) == (False, 4)

    def test_shrink_on_idle(self):
        ec = ElasticController(model_parallel=1, min_data_parallel=2)
        d = ec.decide_ahead(8, range(8), overload_pressure=0.0)
        assert (d.should_rescale, d.new_data_parallel) == (True, 4)
        assert "shrink-ahead" in d.reason
        d2 = ec.decide_ahead(2, range(8), overload_pressure=0.0)
        assert not d2.should_rescale         # floor reached

    def test_mid_pressure_defers_to_reactive_decide(self):
        ec = ElasticController(model_parallel=1)
        d = ec.decide_ahead(4, range(3), (), overload_pressure=0.5)
        assert d == ec.decide(4, range(3))

    def test_threshold_validation(self):
        ec = ElasticController(model_parallel=1)
        with pytest.raises(ValueError):
            ec.decide_ahead(1, range(2), overload_pressure=0.5,
                            grow_threshold=0.2, shrink_threshold=0.4)


# ---------------------------------------------------------------------------
# journal degradation (satellite b) + checkpoint refusal
# ---------------------------------------------------------------------------

class TestJournalDegradation:
    def _fail_writes(self, monkeypatch):
        import repro.serve.ingest as ingest

        real = ingest.atomic_write_npz

        def boom(*a, **kw):
            raise OSError("disk full")

        monkeypatch.setattr(ingest, "atomic_write_npz", boom)
        return lambda: monkeypatch.setattr(ingest, "atomic_write_npz",
                                           real)

    def test_push_survives_write_failure(self, tmp_path, monkeypatch):
        log = TraceLog(str(tmp_path))
        log.append("j", np.ones(4, np.float32))
        log.flush()
        heal = self._fail_writes(monkeypatch)
        log.append("j", 2 * np.ones(4, np.float32))
        with pytest.warns(RuntimeWarning, match="journal"):
            log.flush()                       # degrades, does NOT raise
        assert log.journal_degraded and log.journal_write_errors == 1
        assert log.durable_seq == 1 < log.next_seq
        # pending records still replayable from memory
        assert len(log.records()) == 2
        heal()
        log.flush()                           # retries the same segment
        assert not log.journal_degraded
        assert log.durable_seq == log.next_seq == 2
        # a FRESH reader sees both records on disk
        assert len(TraceLog(str(tmp_path)).records()) == 2

    def test_checkpoint_refuses_degraded_journal(self, tmp_path,
                                                 monkeypatch):
        rsvc = RecoverableTuningService(_bank(), root=str(tmp_path))
        rsvc.submit("a", 48)
        heal = self._fail_writes(monkeypatch)
        with pytest.warns(RuntimeWarning):
            rsvc.push("a", np.ones(8, np.float32))   # accepted, in-memory
        with pytest.raises(RuntimeError, match="journal degraded"):
            rsvc.checkpoint()
        heal()
        rsvc.checkpoint()                     # heals, then succeeds
        rec = RecoverableTuningService.recover(_bank(),
                                               root=str(tmp_path))
        assert rec.svc._front._jobs["a"].pushed == 8


# ---------------------------------------------------------------------------
# control plane snapshots (mid-ladder recovery)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", SEEDS)
def test_recover_mid_ladder_bitwise(tmp_path, seed):
    """Kill an overloaded service mid-burst; the recovered twin resumes
    at the same rung with the same history, same QoS/degraded markers,
    and finishes with bitwise-identical verdicts."""
    streams = _streams(seed=seed)
    kw = dict(overload=OverloadConfig(target_p99=0.01, patience=1,
                                      cooldown=1000, window=64),
              admission=AdmissionPolicy(),
              chaos=FaultPlan(seed=seed, slow_rate=1.0, slow_extra=10.0))
    rsvc = RecoverableTuningService(_bank(), root=str(tmp_path), **kw)
    for j in streams:
        rsvc.submit(j, 48, qos="gold")
    for t in range(3):
        for j, s in streams.items():
            rsvc.push(j, s[t * 8: (t + 1) * 8])
        rsvc.tick()
    assert rsvc.rung >= 1
    rsvc.checkpoint()
    for t in range(3, 5):                     # journal tail past snapshot
        for j, s in streams.items():
            rsvc.push(j, s[t * 8: (t + 1) * 8])
        rsvc.tick()

    rec = RecoverableTuningService.recover(_bank(), root=str(tmp_path))
    assert rec.replayed > 0
    assert rec.rung == rsvc.rung
    assert rec.rung_history == rsvc.rung_history
    for j in streams:
        assert rec.svc._jobs[j].qos == "gold"
        assert (rec.svc._jobs[j].degraded_level
                == rsvc.svc._jobs[j].degraded_level)
    for t in range(5, 6):
        for j, s in streams.items():
            rsvc.push(j, s[t * 8: (t + 1) * 8])
            rec.push(j, s[t * 8: (t + 1) * 8])
        rsvc.tick()
        rec.tick()
    assert (_keyd(rec.finish_many(list(streams)))
            == _keyd(rsvc.finish_many(list(streams))))


@pytest.mark.parametrize("seed", SEEDS)
def test_recover_mid_approx_prob_rung_replays_same_rungs(tmp_path, seed):
    """Kill an exact prob-mode service while the ladder sits ON the new
    ``approx_prob`` rung; the recovered twin resumes at the same rung
    with the same history (so it re-walks the same rungs), carries the
    same degraded markers, and finishes bitwise identical."""
    streams = _streams(seed=seed)
    kw = dict(min_probability=0.5,
              overload=OverloadConfig(target_p99=0.01, patience=1,
                                      cooldown=1000, window=64,
                                      max_rung=1),
              chaos=FaultPlan(seed=seed, slow_rate=1.0, slow_extra=10.0))
    rsvc = RecoverableTuningService(_bank(), root=str(tmp_path), **kw)
    for j in streams:
        rsvc.submit(j, 48)
    for t in range(3):
        for j, s in streams.items():
            rsvc.push(j, s[t * 8: (t + 1) * 8])
        rsvc.tick()
    assert rsvc.rung == 1 and RUNGS[rsvc.rung] == "approx_prob"
    rsvc.checkpoint()
    for t in range(3, 5):                     # journal tail past snapshot
        for j, s in streams.items():
            rsvc.push(j, s[t * 8: (t + 1) * 8])
        rsvc.tick()

    rec = RecoverableTuningService.recover(_bank(), root=str(tmp_path))
    assert rec.replayed > 0
    assert rec.rung == rsvc.rung == 1
    assert rec.rung_history == rsvc.rung_history
    for j in streams:
        assert (rec.svc._jobs[j].degraded_level
                == rsvc.svc._jobs[j].degraded_level == 1)
    for t in range(5, 6):
        for j, s in streams.items():
            rsvc.push(j, s[t * 8: (t + 1) * 8])
            rec.push(j, s[t * 8: (t + 1) * 8])
        rsvc.tick()
        rec.tick()
    assert (_keyd(rec.finish_many(list(streams)))
            == _keyd(rsvc.finish_many(list(streams))))


def test_snapshot_restores_breaker_and_shed_counters():
    br = CircuitBreaker(fail_threshold=1, cooldown=3, probe_interval=4,
                        seed=9)
    svc = TuningService(_bank(), overload=OverloadConfig(),
                        admission=AdmissionPolicy(bronze=0.1, silver=0.1,
                                                  gold=0.1,
                                                  cost_scale=0.01),
                        breaker=br)
    with pytest.raises(AdmissionShedError):
        svc.submit("big", 400, qos="silver")
    br.record_failure()                       # tripped at snapshot time
    tree = snapshot_service(svc)
    br2 = CircuitBreaker(fail_threshold=1, cooldown=3, probe_interval=4,
                         seed=0)
    svc2 = restore_service(tree, _bank(), breaker=br2)
    assert svc2.shed_count == 1 and svc2.shed_by_class == {"silver": 1}
    assert br2.state == br2.OPEN and br2.opened_count == 1
    assert [br.before_dispatch() for _ in range(8)] \
        == [br2.before_dispatch() for _ in range(8)]


# ---------------------------------------------------------------------------
# overload fault classes (chaos plan)
# ---------------------------------------------------------------------------

class TestOverloadFaults:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_streams_deterministic_and_independent(self, seed):
        def trace(plan, n=40):
            return ([plan.spike_multiplier() for _ in range(n)],
                    [plan.slow_dispatch() for _ in range(n)],
                    [plan.queue_burst() for _ in range(n)])

        kw = dict(spike_rate=0.3, spike_factor=10.0, spike_len=2,
                  slow_rate=0.3, slow_extra=0.5, queue_burst_rate=0.3)
        a = trace(FaultPlan(seed=seed, **kw))
        b = trace(FaultPlan(seed=seed, **kw))
        assert a == b
        # enabling dispatch faults must not shift the overload streams
        c = trace(FaultPlan(seed=seed, dispatch_fail_rate=0.9, **kw))
        assert a == c

    def test_spike_windows_and_counters(self):
        plan = FaultPlan(seed=1, spike_rate=1.0, spike_factor=7.0,
                         spike_len=3)
        mults = [plan.spike_multiplier() for _ in range(6)]
        assert mults == [7.0] * 6             # rate 1: wall-to-wall burst
        assert plan.spiked_beats == 6
        calm = FaultPlan(seed=1)
        assert [calm.spike_multiplier() for _ in range(4)] == [1.0] * 4

    def test_slow_dispatch_returns_latency_never_sleeps(self):
        import time
        plan = FaultPlan(seed=2, slow_rate=1.0, slow_extra=123.0)
        t0 = time.perf_counter()
        extras = [plan.slow_dispatch() for _ in range(10)]
        assert time.perf_counter() - t0 < 1.0  # injected, not slept
        assert extras == [123.0] * 10
        assert plan.slowed_dispatches == 10

    def test_queue_burst_windows(self):
        plan = FaultPlan(seed=3, queue_burst_rate=1.0, queue_burst_len=2)
        assert all(plan.queue_burst() for _ in range(6))
        assert plan.queue_bursts >= 1
        assert not FaultPlan(seed=3).queue_burst()
