"""Layered serving front: ingest, scheduler, and the churn invariant.

The refactor's hard pin: a job's decisions — early (matched, corr,
decided_at_fraction) and final — are bit-for-bit independent of slot
packing, admission order, S-bucket capacity history, tick-rate cohorts
and verdict batching.  Randomized submit/evict/finish interleavings must
therefore reproduce a fixed-slot reference run exactly, including runs
that cross S-bucket boundaries and runs with the wavelet prefilter
pruning the bank underneath the slots.

Plus unit coverage of the new layers: bounded ingest queues with both
backpressure policies, trace-log rotation/replay, slot-bucket math,
cohort due-clocks, and multi-tenant routing.
"""
import numpy as np
import pytest

from repro import mrsim
from repro.core.database import SeriesBank, pack_series
from repro.serve.ingest import (BackpressureError, BoundedBuffer, IngestFront,
                                TraceLog)
from repro.serve.scheduler import (MIN_SLOT_BUCKET, SlotScheduler,
                                   TickCohorts, slot_bucket)
from repro.serve.tuning import MultiTenantTuningService, TuningService


@pytest.fixture(scope="module")
def paper_bank():
    from repro.core.filters import preprocess_bank

    psets = mrsim.paper_param_sets()
    series, labels = [], []
    for app in ("wordcount", "terasort"):
        for p in psets:
            series.append(mrsim.simulate_cpu_series(app, p, dt=0.25))
            labels.append(app)
    bank = pack_series(series, labels=labels)
    return SeriesBank(preprocess_bank(bank.series, bank.lengths),
                      bank.lengths, bank.labels, bank.entries)


# ---------------------------------------------------------------------------
# ingest: bounded queues
# ---------------------------------------------------------------------------

def test_bounded_buffer_reject_is_atomic():
    buf = BoundedBuffer(limit=8, policy="reject")
    buf.append(np.arange(6, dtype=np.float32))
    with pytest.raises(BackpressureError, match="buffer full"):
        buf.append(np.arange(3, dtype=np.float32))
    # nothing partially enqueued: the same chunk fits after a drain
    assert len(buf) == 6 and buf.dropped == 0
    got = buf.drain()
    np.testing.assert_array_equal(got, np.arange(6, dtype=np.float32))
    buf.append(np.arange(3, dtype=np.float32))
    assert len(buf) == 3


def test_bounded_buffer_drop_oldest_sheds_from_front():
    buf = BoundedBuffer(limit=8, policy="drop_oldest")
    buf.append(np.arange(6, dtype=np.float32))
    buf.append(10 + np.arange(4, dtype=np.float32))   # sheds 2 oldest
    assert buf.dropped == 2 and len(buf) == 8
    np.testing.assert_array_equal(
        buf.drain(), np.concatenate([np.arange(2, 6),
                                     10 + np.arange(4)]).astype(np.float32))
    # a single chunk larger than the whole queue keeps only its tail
    buf.append(np.arange(20, dtype=np.float32))
    assert len(buf) == 8 and buf.dropped == 2 + 12
    np.testing.assert_array_equal(
        buf.drain(), np.arange(12, 20, dtype=np.float32))


def test_service_backpressure_policies(paper_bank):
    svc = TuningService(paper_bank, queue_limit=16, queue_policy="reject")
    svc.submit("j", expected_len=64)
    svc.push("j", np.zeros(16, np.float32))
    with pytest.raises(BackpressureError):
        svc.push("j", np.zeros(1, np.float32))
    svc.tick()                                        # drains the queue
    svc.push("j", np.zeros(16, np.float32))           # accepted again


# ---------------------------------------------------------------------------
# ingest: trace log
# ---------------------------------------------------------------------------

def test_trace_log_rotation_and_replay(tmp_path):
    log = TraceLog(str(tmp_path), max_segment_bytes=4 * 8, max_segments=2)
    rng = np.random.default_rng(0)
    a_parts, b_parts = [], []
    for i in range(6):
        ca = rng.normal(size=4).astype(np.float32)
        cb = rng.normal(size=4).astype(np.float32)
        log.append("a", ca)
        log.append("b", cb)
        a_parts.append(ca)
        b_parts.append(cb)
    log.flush()
    # rotation kept only the newest max_segments files
    assert len(log.segments()) == 2
    import os
    on_disk = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert on_disk == sorted(log.segments())
    # replay returns the RETAINED window, in ingest order, per job
    got_a = log.read_job("a")
    want_a = np.concatenate(a_parts)
    assert got_a.shape[0] < want_a.shape[0]           # oldest rotated out
    np.testing.assert_array_equal(got_a, want_a[-got_a.shape[0]:])
    assert log.read_job("nope").shape == (0,)


def test_service_traces_accepted_pushes(tmp_path, paper_bank):
    log = TraceLog(str(tmp_path))
    svc = TuningService(paper_bank, trace_log=log)
    q = np.linspace(0, 1, 32, dtype=np.float32)
    svc.submit("j", expected_len=32)
    for lo in range(0, 32, 8):
        svc.push("j", q[lo: lo + 8])
        svc.tick()
    svc.finish("j")
    log.flush()
    np.testing.assert_array_equal(log.read_job("j"), q)


# ---------------------------------------------------------------------------
# scheduler: buckets and cohorts
# ---------------------------------------------------------------------------

def test_slot_bucket_math():
    assert slot_bucket(0, 64) == MIN_SLOT_BUCKET
    assert slot_bucket(4, 64) == 4
    assert slot_bucket(5, 64) == 8
    assert slot_bucket(9, 64) == 16
    assert slot_bucket(100, 64) == 64                 # clamped
    assert slot_bucket(3, 2) == 2                     # max below floor


def test_scheduler_grow_release_shrink():
    sched = SlotScheduler(64)
    assert sched.capacity == MIN_SLOT_BUCKET
    for i in range(4):
        slot, grow = sched.admit(f"j{i}")
        assert grow is None and slot == i
    slot, grow = sched.admit("j4")                    # crosses 4 -> 8
    assert sched.capacity == 8 and slot == 4
    np.testing.assert_array_equal(grow, [0, 1, 2, 3, -1, -1, -1, -1])
    # release three, leaving j1 and j4: shrink compacts them (stable)
    for jid in ("j0", "j2", "j3"):
        sched.release(jid)
    src, moves = sched.shrink_plan()
    assert sched.capacity == 4
    np.testing.assert_array_equal(src, [1, 4, -1, -1])
    assert moves == {"j1": 0, "j4": 1}
    assert sched.slot_of("j4") == 1
    assert sched.shrink_plan() is None                # already minimal


def test_scheduler_max_slots_pin():
    sched = SlotScheduler(2)
    sched.admit("a")
    sched.admit("b")
    with pytest.raises(RuntimeError, match="slots busy"):
        sched.admit("c")
    with pytest.raises(ValueError, match="already scheduled"):
        sched.admit("a")


def test_tick_cohorts_due_clocks():
    c = TickCohorts()
    c.assign("fast", 100.0)
    c.assign("slow", 4.0)
    c.assign("always", None)
    assert c.n_cohorts == 3
    # first beat: everyone due (clocks start at -inf)
    assert c.due_jobs(0.0) == {"fast", "slow", "always"}
    # 10 ms later only the 100 Hz cohort (and the unrated job) is due
    assert c.due_jobs(0.01) == {"fast", "always"}
    # the 4 Hz cohort re-arms at 0.25 s
    assert "slow" not in c.due_jobs(0.2)
    assert "slow" in c.due_jobs(0.26)
    # clock-less query = legacy drain-everything
    assert c.due_jobs(None) == {"fast", "slow", "always"}


def test_service_cohorts_meter_drains(paper_bank):
    p = mrsim.paper_param_sets()[0]
    q = mrsim.simulate_cpu_series("exim", p, dt=0.25)
    svc = TuningService(paper_bank, band=16, denoise=True)
    svc.submit("fast", expected_len=len(q), tick_hz=100.0)
    svc.submit("slow", expected_len=len(q), tick_hz=4.0)
    lo = 0
    for t in range(20):                               # 100 Hz wall clock
        svc.push("fast", q[lo: lo + 4])
        svc.push("slow", q[lo: lo + 4])
        lo += 4
        svc.tick(now=t / 100.0)
    fast, slow = svc._jobs["fast"], svc._jobs["slow"]
    assert fast.n == 80                               # drained every beat
    # the slow cohort was touched only on its own period: 0.20 s of wall
    # clock at 4 Hz = the t=0 beat, nothing else due before 0.25 s; the
    # other 76 samples just sit in the ingest queue (none lost).
    assert slow.n == 4 and slow.x.view().shape[0] == 4
    assert len(svc._front._jobs["slow"].buffer) == 76
    assert svc.dispatch_count <= svc.ticks
    d = svc.finish_many(["fast", "slow"])
    assert d["fast"].corr == d["slow"].corr           # same data, same verdict


# ---------------------------------------------------------------------------
# churn invariance: the refactor's hard pin
# ---------------------------------------------------------------------------

def _job_chunks(q, rng):
    """Fixed per-job chunk schedule (identical in every run)."""
    chunks, lo = [], 0
    while lo < len(q):
        c = int(rng.integers(4, 24))
        chunks.append(q[lo: lo + c])
        lo += c
    return chunks


def _decision_key(d):
    return None if d is None else (d.matched, d.corr, d.decided_at_fraction,
                                   tuple(sorted(d.scores.items())))


def _reference_run(bank, jobs, **kw):
    """Fixed-slot, fixed-order baseline: all jobs submitted up front,
    chunk i consumed at tick i, sequential finishes."""
    svc = TuningService(bank, elastic_slots=False, **kw)
    for jid, chunks in jobs.items():
        svc.submit(jid, expected_len=sum(len(c) for c in chunks))
    early = {}
    for t in range(max(len(c) for c in jobs.values())):
        for jid, chunks in jobs.items():
            if t < len(chunks):
                svc.push(jid, chunks[t])
        for jid, d in svc.tick().items():
            if d is not None:
                early.setdefault(jid, d)
    finals = {jid: svc.finish(jid) for jid in jobs}
    return early, finals


def _churned_run(bank, jobs, seed, **kw):
    """Elastic slots, randomized admission order + staggered starts,
    decoy jobs evicted mid-run (forcing compaction + slot moves), and
    grouped/deferred finishes.  Job j still consumes chunk i at its i-th
    data tick, so the information schedule matches the reference."""
    rng = np.random.default_rng(seed)
    svc = TuningService(bank, **kw)
    order = list(jobs)
    rng.shuffle(order)
    start = {jid: int(rng.integers(0, 4)) for jid in order}
    decoys = {}
    early, finals, t = {}, {}, 0
    live = set()
    while len(finals) < len(jobs):
        for jid in order:                   # staggered admissions
            if start[jid] == t:
                svc.submit(jid, expected_len=sum(
                    len(c) for c in jobs[jid]))
                live.add(jid)
        if t == 1:                          # decoys force bucket growth
            for i in range(3):
                d = f"decoy{i}"
                svc.submit(d, expected_len=64)
                decoys[d] = 0
        for jid in sorted(live):
            k = t - start[jid]
            if k < len(jobs[jid]):
                svc.push(jid, jobs[jid][k])
        for d in list(decoys):
            svc.push(d, np.full(8, 0.5, np.float32))
            decoys[d] += 1
        for jid, d in svc.tick().items():
            if d is not None and jid in jobs:
                early.setdefault(jid, d)
        if t == 4:                          # evict decoys mid-run
            for d in list(decoys):
                svc.evict(d)
                del decoys[d]
        done = [jid for jid in sorted(live)
                if t - start[jid] + 1 >= len(jobs[jid])]
        if done:
            if rng.integers(2):             # grouped batch finish
                finals.update(svc.finish_many(done))
            else:                           # deferred drain queue
                for jid in done:
                    svc.finish_later(jid)
                finals.update(svc.drain_finishes())
            live.difference_update(done)
        t += 1
    assert svc.slot_repack_count > 0        # buckets actually crossed
    assert svc.evicted_count == 3
    return early, finals


@pytest.mark.parametrize("seed", [0, 1])
def test_churn_invariance(paper_bank, seed):
    rng = np.random.default_rng(100 + seed)
    psets = mrsim.paper_param_sets()
    jobs = {}
    for i, (app, run) in enumerate((("wordcount", 1), ("exim", 2),
                                    ("terasort", 1), ("exim", 3))):
        q = mrsim.simulate_cpu_series(app, psets[i % len(psets)], run=run,
                                      dt=0.25)
        jobs[f"{app}{i}"] = _job_chunks(q, rng)

    kw = dict(band=16, threshold=0.85, margin=0.02, stable_ticks=2,
              min_fraction=0.15, denoise=True, slots=16)
    early_ref, fin_ref = _reference_run(paper_bank, jobs, **kw)
    early_chn, fin_chn = _churned_run(paper_bank, jobs, seed, **kw)

    # bit-for-bit: same early decisions (matched, corr,
    # decided_at_fraction, full score dict) and same final verdicts,
    # regardless of slot packing, admission order or capacity history.
    assert early_ref.keys() == early_chn.keys()
    for jid in early_ref:
        assert _decision_key(early_ref[jid]) == _decision_key(early_chn[jid])
    for jid in jobs:
        assert _decision_key(fin_ref[jid]) == _decision_key(fin_chn[jid])


def test_churn_invariance_with_prefilter(paper_bank):
    """S-axis churn composes with K-axis pruning: the prefiltered churned
    run still reproduces the prefiltered fixed-slot run bitwise."""
    rng = np.random.default_rng(7)
    psets = mrsim.paper_param_sets()
    jobs = {}
    for i, app in enumerate(("wordcount", "exim", "terasort")):
        q = mrsim.simulate_cpu_series(app, psets[i], run=1, dt=0.25)
        jobs[f"{app}{i}"] = _job_chunks(q, rng)

    kw = dict(band=16, threshold=0.85, margin=0.02, stable_ticks=2,
              min_fraction=0.15, denoise=True, slots=16,
              prefilter_top=2, prefilter_margin=0.02)
    early_ref, fin_ref = _reference_run(paper_bank, jobs, **kw)
    early_chn, fin_chn = _churned_run(paper_bank, jobs, 7, **kw)

    assert early_ref.keys() == early_chn.keys()
    for jid in early_ref:
        assert _decision_key(early_ref[jid]) == _decision_key(early_chn[jid])
    for jid in jobs:
        assert _decision_key(fin_ref[jid]) == _decision_key(fin_chn[jid])


# ---------------------------------------------------------------------------
# multi-tenant front
# ---------------------------------------------------------------------------

def test_multi_tenant_routing_and_isolation(paper_bank):
    # tenant B sees only the first half of the bank (its own references)
    half = len(paper_bank) // 2
    sub = SeriesBank(paper_bank.series[:half], paper_bank.lengths[:half],
                     paper_bank.labels[:half], paper_bank.entries[:half])
    front = MultiTenantTuningService({"A": paper_bank, "B": sub},
                                     band=16, denoise=True)
    assert front.tenants == ("A", "B")

    p = mrsim.paper_param_sets()[0]
    q = mrsim.simulate_cpu_series("wordcount", p, dt=0.25)
    front.submit("ja", expected_len=len(q), tenant="A")
    front.submit("jb", expected_len=len(q), tenant="B")
    with pytest.raises(ValueError, match="already in flight"):
        front.submit("ja", expected_len=8, tenant="B")
    with pytest.raises(KeyError, match="unknown tenant"):
        front.submit("jc", expected_len=8, tenant="C")

    ticks = 0
    for lo in range(0, len(q), 16):
        front.push("ja", q[lo: lo + 16])
        front.push("jb", q[lo: lo + 16])
        front.tick()
        ticks += 1
    # per-engine dispatch bound: data-ticks x tenants
    assert front.dispatch_count <= ticks * 2
    assert front.n_active == 2

    d = front.finish_many(["ja", "jb"])
    # same query, but each verdict is scored against the TENANT's bank:
    # B's score dict only covers the sub-bank's workloads
    assert set(d["ja"].scores) == set(paper_bank.labels)
    assert set(d["jb"].scores) == set(sub.labels)
    assert front.n_active == 0

    # isolation: a single-tenant service over the same sub-bank renders
    # the identical verdict for B's job
    solo = TuningService(sub, band=16, denoise=True)
    solo.submit("jb", expected_len=len(q))
    for lo in range(0, len(q), 16):
        solo.push("jb", q[lo: lo + 16])
        solo.tick()
    want = solo.finish("jb")
    assert d["jb"].matched == want.matched
    assert d["jb"].corr == want.corr
