"""Batched bank matching engine vs the scalar per-pair loop.

The tentpole invariant: packing K ragged references into one padded
[K, M] bank and solving every DP in a single dispatch must reproduce the
scalar ``dtw_distance`` / ``similarity`` loop to float tolerance — padding
and per-series masks change the dispatch shape, never the math.
"""
import numpy as np
import pytest

from repro.core import dtw, similarity, similarity_bank, match_series
from repro.core.database import ReferenceDB, SeriesBank, pack_series
from repro.kernels.dtw import dtw_distances, dtw_distances_pairs


def _ragged(rng, lengths):
    return [rng.normal(size=l).astype(np.float32) for l in lengths]


@pytest.fixture(scope="module")
def ragged_set():
    rng = np.random.default_rng(42)
    x = rng.normal(size=47).astype(np.float32)
    series = _ragged(rng, (19, 64, 33, 5, 64, 50, 12))
    return x, series, pack_series(series)


def test_pack_series_layout(ragged_set):
    _, series, bank = ragged_set
    assert bank.series.shape[1] % 8 == 0
    for k, s in enumerate(series):
        np.testing.assert_array_equal(bank.row(k), s)
        # padding repeats the edge value
        assert (bank.series[k, len(s):] == s[-1]).all()


def test_distance_bank_matches_scalar_loop(ragged_set):
    x, series, bank = ragged_set
    got = np.asarray(dtw.dtw_distance_bank(x, bank.series, bank.lengths))
    want = np.array([float(dtw.dtw_distance(x, s)) for s in series])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_distance_bank_banded_matches_scalar_loop(ragged_set):
    x, series, bank = ragged_set
    band = 6
    got = np.asarray(
        dtw.dtw_distance_bank(x, bank.series, bank.lengths, band=band))
    want = np.array([float(dtw.dtw_matrix_banded(x, s, band=band)[-1, -1])
                     for s in series])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matrix_bank_slices_match_scalar(ragged_set):
    x, series, bank = ragged_set
    D = np.asarray(dtw.dtw_matrix_bank(x, bank.series, bank.lengths))
    Db = np.asarray(
        dtw.dtw_matrix_bank(x, bank.series, bank.lengths, band=7))
    for k, s in enumerate(series):
        np.testing.assert_allclose(D[k, :, :len(s)],
                                   np.asarray(dtw.dtw_matrix(x, s)),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(
            Db[k, :, :len(s)],
            np.asarray(dtw.dtw_matrix_banded(x, s, band=7)),
            rtol=1e-4, atol=1e-4)


def test_matrix_pairs_ragged_both_sides():
    rng = np.random.default_rng(7)
    qs = _ragged(rng, (31, 9, 24))
    rs = _ragged(rng, (17, 40, 26))
    qb, rb = pack_series(qs), pack_series(rs)
    D = np.asarray(dtw.dtw_matrix_pairs(qb.series, rb.series,
                                        qb.lengths, rb.lengths, band=5))
    for p in range(3):
        want = np.asarray(dtw.dtw_matrix_banded(qs[p], rs[p], band=5))
        np.testing.assert_allclose(D[p, :len(qs[p]), :len(rs[p])], want,
                                   rtol=1e-4, atol=1e-4)


def test_similarity_bank_matches_scalar_loop(ragged_set):
    """The matrix path reproduces the scalar loop to float tolerance; the
    default (matrix-free moment scorer) agrees to warp-path-tie tolerance
    on this continuous-noise data — float rounding differences between
    the wavefront and the min-plus scan can flip near-tie backtrack
    choices, which moves individual warp paths by ~1e-3 but never the
    ranking-scale structure (exactness is pinned on tie-free data in
    tests/test_scored_matching.py)."""
    x, series, bank = ragged_set
    for band in (None, 8):
        want = np.array([similarity(x, s, band=band) for s in series])
        got_matrix = similarity_bank(x, bank, band=band, matrix_path=True)
        np.testing.assert_allclose(got_matrix, want, rtol=1e-4, atol=1e-4)
        got = similarity_bank(x, bank, band=band)
        np.testing.assert_allclose(got, want, atol=5e-3)


def test_preprocess_bank_rows_equal_scalar_preprocess(ragged_set):
    from repro.core import filters
    _, series, bank = ragged_set
    pb = np.asarray(filters.preprocess_bank(bank.series, bank.lengths))
    for k, s in enumerate(series):
        want = np.asarray(filters.preprocess(s))
        np.testing.assert_allclose(pb[k, :len(s)], want, rtol=1e-6, atol=1e-6)
        assert (pb[k, len(s):] == want[-1]).all()   # edge padding preserved


def test_similarity_bank_preprocessed_matches_scalar_loop(ragged_set):
    x, series, bank = ragged_set
    want = np.array([similarity(x, s, preprocess=True, band=8)
                     for s in series])
    got_matrix = similarity_bank(x, bank, preprocess=True, band=8,
                                 matrix_path=True)
    np.testing.assert_allclose(got_matrix, want, rtol=1e-4, atol=1e-4)
    got = similarity_bank(x, bank, preprocess=True, band=8)
    np.testing.assert_allclose(got, want, atol=5e-3)


def test_match_series_is_batched_equivalent(ragged_set):
    x, series, bank = ragged_set
    refs = {f"r{k}": s for k, s in enumerate(series)}
    got = match_series(x, refs, preprocess=False, band=4)
    # same engine as similarity_bank: exact agreement
    sims = similarity_bank(x, bank, band=4)
    for k, name in enumerate(refs):
        assert got[name] == sims[k]
        # scalar loop to warp-path-tie tolerance (see
        # test_similarity_bank_matches_scalar_loop)
        assert got[name] == pytest.approx(
            similarity(x, series[k], band=4), abs=5e-3)


def test_similarity_surfaces_negative_correlation():
    t = np.linspace(0, 1, 60, dtype=np.float32)
    assert similarity(t, (1.0 - t)) < 0.0  # anti-correlated, not clipped


def test_kernel_distances_respect_lengths(ragged_set):
    x, series, bank = ragged_set
    got = np.asarray(dtw_distances(x, bank.series, lengths=bank.lengths))
    want = np.array([float(dtw.dtw_distance(x, s)) for s in series])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_similarity_bank_rejects_lengths_for_ragged_input(ragged_set):
    x, series, _ = ragged_set
    with pytest.raises(ValueError):
        similarity_bank(x, series, np.array([3] * len(series)))


def test_similarity_bank_rejects_bare_1d_reference(ragged_set):
    x, series, _ = ragged_set
    with pytest.raises(ValueError, match=r"\[K, M\]"):
        similarity_bank(x, series[0])            # must be [series[0]]
    got = similarity_bank(x, [series[0]])        # the loud message's fix
    assert got.shape == (1,)


def test_kernel_pairs_distances(ragged_set):
    x, series, bank = ragged_set
    k = len(series)
    xs = np.tile(x, (k, 1))
    got = np.asarray(dtw_distances_pairs(xs, bank.series,
                                         ylens=bank.lengths))
    want = np.array([float(dtw.dtw_distance(x, s)) for s in series])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_db_bank_caching_and_invalidation():
    rng = np.random.default_rng(3)
    db = ReferenceDB()
    db.add("a", {"j": 0}, rng.normal(size=30))
    db.add("b", {"j": 0}, rng.normal(size=41))
    b1 = db.bank()
    assert db.bank() is b1 and b1.labels == ("a", "b")
    sub = db.bank(workloads=["b"])
    assert sub.labels == ("b",) and len(sub) == 1
    db.add("c", {"j": 0}, rng.normal(size=12))
    b2 = db.bank()
    assert b2 is not b1 and len(b2) == 3
    # LRU bound: distinct selections never grow the cache past the cap
    for i in range(3 * ReferenceDB.BANK_CACHE_MAX):
        db.add(f"w{i}", {}, rng.normal(size=8))
        db.bank(exclude=[f"w{i}"])
    assert len(db._bank_cache) <= ReferenceDB.BANK_CACHE_MAX


def test_db_load_with_adversarial_meta_keys(tmp_path):
    db = ReferenceDB()
    db.add("w", {"M": 1}, np.ones(16, np.float32),
           meta={"workload": "shadow", "params": {"x": 1}, "series": [0]})
    db.save(str(tmp_path / "db"))
    db2 = ReferenceDB.load(str(tmp_path / "db"))
    e = db2.entries[0]
    assert e.workload == "w" and e.params == {"M": 1}
    assert e.meta["workload"] == "shadow"
