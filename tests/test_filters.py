"""Chebyshev design + filtering (paper §3.1.1)."""
import numpy as np
import pytest
import scipy.signal as ss

from _hypothesis_compat import given, settings, st

from repro.core import filters


@pytest.mark.parametrize("order,ripple,cutoff", [
    (6, 1.0, 0.125), (6, 0.5, 0.3), (4, 2.0, 0.05), (2, 1.0, 0.6),
    (7, 1.0, 0.2), (3, 3.0, 0.9),
])
def test_cheby1_matches_scipy(order, ripple, cutoff):
    b, a = filters.cheby1_design(order, ripple, cutoff)
    bs, as_ = ss.cheby1(order, ripple, cutoff)
    np.testing.assert_allclose(b, bs, atol=1e-9)
    np.testing.assert_allclose(a, as_, atol=1e-9)


def test_lfilter_matches_scipy():
    b, a = filters.cheby1_design(6, 1.0, 0.125)
    x = np.random.default_rng(0).normal(size=(4, 300)).astype(np.float32)
    y = np.asarray(filters.lfilter(b, a, x))
    ys = ss.lfilter(b, a, x, axis=-1)
    np.testing.assert_allclose(y, ys, atol=5e-3)


def test_denoise_reduces_noise_power():
    rng = np.random.default_rng(1)
    t = np.linspace(0, 10, 500)
    clean = np.sin(2 * np.pi * 0.2 * t)
    noisy = clean + rng.normal(0, 0.3, size=t.shape)
    den = np.asarray(filters.denoise(noisy.astype(np.float32)))
    assert np.mean((den - clean) ** 2) < 0.5 * np.mean((noisy - clean) ** 2)


@given(st.integers(0, 2**31 - 1), st.integers(30, 200))
@settings(max_examples=20, deadline=None)
def test_normalize01_bounds(seed, n):
    x = np.random.default_rng(seed).normal(size=n).astype(np.float32) * 10
    y = np.asarray(filters.normalize01(x))
    assert y.min() >= -1e-6 and y.max() <= 1 + 1e-6
    assert y.max() >= 1 - 1e-5  # hits both bounds


def test_preprocess_pipeline_shape_and_range():
    x = np.random.default_rng(2).normal(size=(3, 128)).astype(np.float32)
    y = np.asarray(filters.preprocess(x))
    assert y.shape == x.shape
    assert np.isfinite(y).all()
    assert (y >= -1e-6).all() and (y <= 1 + 1e-6).all()
